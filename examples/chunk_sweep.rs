//! Chunk-size sweep (the Fig. 5c story): theory *and* bit-accurate
//! measurement of the VRR as the chunk size sweeps from 1 to n, showing
//! the flat maximum — "the exact choice of a chunking size is not of
//! paramount importance" as long as it is neither too small nor too
//! large.
//!
//! The whole sweep is **one** [`sweep_vrr`] engine call: every chunk
//! size (and the unchunked baseline) is scored against the *same* drawn
//! Monte-Carlo ensemble, so the expensive draw-and-quantize pass runs
//! once instead of once per row — and the rows are directly comparable,
//! with zero between-row sampling noise.
//!
//! ```sh
//! cargo run --release --example chunk_sweep -- --n 65536 --macc 8
//! ```

use abws::coordinator::sweep::default_threads;
use abws::mc::{sweep_vrr, AccumSetup, Ensemble};
use abws::util::argparse::Args;
use abws::vrr::chunking::vrr_chunked_total;
use abws::vrr::theorem::vrr;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("n", 65_536);
    let m_acc = args.get_u32("macc", 8);
    let trials = args.get_usize("trials", 96);

    let mut chunks = vec![];
    let mut c = 1usize;
    while c <= n {
        chunks.push(c);
        c *= 4;
    }

    // One grid: every chunk size, plus the unchunked baseline last.
    let mut grid: Vec<AccumSetup> = chunks
        .iter()
        .map(|&c| AccumSetup::new(m_acc).with_chunk(c))
        .collect();
    grid.push(AccumSetup::new(m_acc));
    let ens = Ensemble {
        n,
        m_p: 5,
        e_acc: 6,
        sigma_p: 1.0,
        trials,
        seed: 0x5eed,
        threads: default_threads(),
    };
    let results = sweep_vrr(&ens, &grid)?;

    println!("VRR vs chunk size  (n={n}, m_acc={m_acc}, m_p=5)");
    println!("{:>9} {:>12} {:>12}", "chunk", "theory", "measured");
    for (&chunk, r) in chunks.iter().zip(&results) {
        let theory = vrr_chunked_total(m_acc, 5, n, chunk);
        println!("{chunk:>9} {theory:>12.5} {:>12.5}", r.vrr);
    }
    let plain = results.last().expect("unchunked baseline");
    println!(
        "{:>9} {:>12.5} {:>12.5}  (no chunking — the dashed line of Fig. 5c)",
        "none",
        vrr(m_acc, 5, n),
        plain.vrr
    );
    Ok(())
}
