//! Chunk-size sweep (the Fig. 5c story): theory *and* bit-accurate
//! measurement of the VRR as the chunk size sweeps from 1 to n, showing
//! the flat maximum — "the exact choice of a chunking size is not of
//! paramount importance" as long as it is neither too small nor too
//! large.
//!
//! ```sh
//! cargo run --release --example chunk_sweep -- --n 65536 --macc 8
//! ```

use abws::coordinator::sweep::run_sweep;
use abws::mc::{empirical_vrr, McConfig};
use abws::util::argparse::Args;
use abws::vrr::chunking::vrr_chunked_total;
use abws::vrr::theorem::vrr;

fn main() {
    let args = Args::from_env();
    let n = args.get_usize("n", 65_536);
    let m_acc = args.get_u32("macc", 8);
    let trials = args.get_usize("trials", 96);

    let mut chunks = vec![];
    let mut c = 1usize;
    while c <= n {
        chunks.push(c);
        c *= 4;
    }

    println!("VRR vs chunk size  (n={n}, m_acc={m_acc}, m_p=5)");
    println!(
        "{:>9} {:>12} {:>12}",
        "chunk", "theory", "measured"
    );
    let plain = vrr(m_acc, 5, n);

    let rows = run_sweep(chunks, 4, |&chunk| {
        let theory = vrr_chunked_total(m_acc, 5, n, chunk);
        let measured = empirical_vrr(
            &McConfig::new(n, m_acc)
                .with_chunk(chunk)
                .with_trials(trials),
        )
        .vrr;
        (chunk, theory, measured)
    });
    for (chunk, theory, measured) in rows {
        println!("{chunk:>9} {theory:>12.5} {measured:>12.5}");
    }
    println!(
        "{:>9} {plain:>12.5}  (no chunking — the dashed line of Fig. 5c)",
        "none"
    );
}
