//! Precision advisor: the hardware-designer workflow the paper's
//! conclusion describes — feed in *your* layer shapes, get back the
//! minimum accumulator widths for FWD/BWD/GRAD, normal and chunked,
//! without "computationally prohibitive brute-force emulations".
//!
//! ```sh
//! cargo run --release --example precision_advisor -- \
//!     --batch 256 --conv 3x64x7x112 --conv 64x128x3x56 --fc 4096x1000 \
//!     --nzr-grad 0.5 --chunk 64
//! ```
//!
//! Layer syntax: `--conv CIN x COUT x K x HOUT`  (square kernels/maps),
//!               `--fc CIN x COUT`.

use abws::nets::layer::{Layer, Network};
use abws::nets::lengths::{accum_lengths, Gemm};
use abws::nets::nzr::NzrModel;
use abws::nets::predict::predict_network;
use abws::util::argparse::Args;

fn parse_dims(spec: &str) -> Vec<usize> {
    spec.split('x')
        .map(|t| t.trim().parse().expect("layer dims must be integers"))
        .collect()
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(argv.iter().cloned());

    // Collect layers in argv order (Args keeps only the last value per
    // key, so scan the raw argv for repeatable --conv/--fc options).
    let mut layers = Vec::new();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--conv" => {
                let d = parse_dims(&argv[i + 1]);
                assert_eq!(d.len(), 4, "--conv CINxCOUTxKxHOUT");
                let idx = layers.len();
                layers.push(Layer::conv(
                    &format!("conv{idx}"),
                    &format!("Layer {idx}"),
                    d[0],
                    d[1],
                    d[2],
                    d[3],
                    d[3],
                ));
                i += 2;
            }
            "--fc" => {
                let d = parse_dims(&argv[i + 1]);
                assert_eq!(d.len(), 2, "--fc CINxCOUT");
                let idx = layers.len();
                layers.push(Layer::fc(
                    &format!("fc{idx}"),
                    &format!("Layer {idx}"),
                    d[0],
                    d[1],
                ));
                i += 2;
            }
            _ => i += 1,
        }
    }
    if layers.is_empty() {
        // A sensible demo network if none was given.
        layers = vec![
            Layer::conv("conv0", "Layer 0", 3, 64, 7, 112, 112),
            Layer::conv("conv1", "Layer 1", 64, 128, 3, 28, 28),
            Layer::fc("fc", "Layer 2", 2048, 1000),
        ];
        println!("(no layers given — using a demo stem; see the header for syntax)\n");
    }

    let net = Network {
        name: "custom".into(),
        batch: args.get_usize("batch", 256),
        layers,
        first_layer: 0,
    };
    let nzr = NzrModel::uniform(
        args.get_f64("nzr-fwd", 1.0),
        args.get_f64("nzr-bwd", 0.5),
        args.get_f64("nzr-grad", 0.5),
    );
    let chunk = args.get_usize("chunk", 64);
    let m_p = args.get_u32("mp", 5);

    let pred = predict_network(&net, &nzr, m_p, chunk);
    println!(
        "{:<10} {:<10} {:>10} {:>16} {:>16}",
        "layer", "gemm", "length", "m_acc (normal)", "m_acc (chunked)"
    );
    for (layer, lp) in net.layers.iter().zip(&pred.layers) {
        let lengths = accum_lengths(&net, layer);
        for gemm in Gemm::ALL {
            if let Some(Some(p)) = lp.per_gemm.get(gemm.name()) {
                println!(
                    "{:<10} {:<10} {:>10} {:>16} {:>16}",
                    lp.layer,
                    gemm.name(),
                    lengths.get(gemm),
                    p.normal,
                    p.chunked
                );
            }
        }
    }
    println!(
        "\nAccumulator format: (1, 6, m_acc) floating-point; inputs (1,5,2); \
         cut-off v(n) < 50 (paper Eq. 6)."
    );
}
