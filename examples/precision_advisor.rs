//! Precision advisor: the hardware-designer workflow the paper's
//! conclusion describes — feed in *your* layer shapes, get back the
//! minimum accumulator widths for FWD/BWD/GRAD, normal and chunked,
//! without "computationally prohibitive brute-force emulations".
//!
//! ```sh
//! cargo run --release --example precision_advisor -- \
//!     --batch 256 --conv 3x64x7x112 --conv 64x128x3x56 --fc 4096x1000 \
//!     --nzr-grad 0.5 --chunk 64
//! ```
//!
//! Layer syntax: `--conv CIN x COUT x K x HOUT`  (square kernels/maps),
//!               `--fc CIN x COUT`; both options repeat and layers are
//! taken in argv order. Pass `--json` for the machine-readable
//! `AdvisorReport` instead of the table (the same document `abws serve`
//! streams).

use abws::api::{AdvisorRequest, PrecisionPolicy};
use abws::nets::layer::{Layer, Network};
use abws::nets::lengths::Gemm;
use abws::nets::nzr::NzrModel;
use abws::util::argparse::Args;
use anyhow::{ensure, Context, Result};

fn parse_dims(spec: &str) -> Result<Vec<usize>> {
    spec.split('x')
        .map(|t| {
            t.trim()
                .parse()
                .with_context(|| format!("bad layer dims '{spec}': '{t}' is not an integer"))
        })
        .collect()
}

fn main() -> Result<()> {
    let args = Args::from_env();

    // Repeatable --conv/--fc options, interleaved in argv order (the
    // network is the argv sequence; `Args::get_all` gives per-key lists,
    // `Args::entries` the cross-key order we need here).
    let mut layers = Vec::new();
    for (key, spec) in args.entries() {
        let idx = layers.len();
        match key {
            "conv" => {
                let d = parse_dims(spec)?;
                ensure!(d.len() == 4, "--conv expects CINxCOUTxKxHOUT, got '{spec}'");
                layers.push(Layer::conv(
                    &format!("conv{idx}"),
                    &format!("Layer {idx}"),
                    d[0],
                    d[1],
                    d[2],
                    d[3],
                    d[3],
                ));
            }
            "fc" => {
                let d = parse_dims(spec)?;
                ensure!(d.len() == 2, "--fc expects CINxCOUT, got '{spec}'");
                layers.push(Layer::fc(
                    &format!("fc{idx}"),
                    &format!("Layer {idx}"),
                    d[0],
                    d[1],
                ));
            }
            _ => {}
        }
    }
    if layers.is_empty() {
        // A sensible demo network if none was given.
        layers = vec![
            Layer::conv("conv0", "Layer 0", 3, 64, 7, 112, 112),
            Layer::conv("conv1", "Layer 1", 64, 128, 3, 28, 28),
            Layer::fc("fc", "Layer 2", 2048, 1000),
        ];
        println!("(no layers given — using a demo stem; see the header for syntax)\n");
    }

    let net = Network {
        name: "custom".into(),
        batch: args.get_usize("batch", 256),
        layers,
        first_layer: 0,
    };
    let policy = PrecisionPolicy::builder()
        .m_p(args.get_u32("mp", 5))
        .chunk(args.get_usize("chunk", 64))
        .nzr(NzrModel::uniform(
            args.get_f64("nzr-fwd", 1.0),
            args.get_f64("nzr-bwd", 0.5),
            args.get_f64("nzr-grad", 0.5),
        ))
        .build()?;

    let report = AdvisorRequest::custom(net, policy).run()?;
    if args.flag("json") {
        println!("{}", report.to_json());
        return Ok(());
    }

    println!(
        "{:<10} {:<10} {:>10} {:>16} {:>16}",
        "layer", "gemm", "length", "m_acc (normal)", "m_acc (chunked)"
    );
    for lp in &report.prediction.layers {
        for gemm in Gemm::ALL {
            if let Some(Some(p)) = lp.per_gemm.get(gemm.name()) {
                println!(
                    "{:<10} {:<10} {:>10} {:>16} {:>16}",
                    lp.layer,
                    gemm.name(),
                    lp.lengths.get(gemm),
                    p.normal,
                    p.chunked
                );
            }
        }
    }
    println!(
        "\nAccumulator format: (1, 6, m_acc) floating-point; inputs (1,5,2); \
         cut-off v(n) < 50 (paper Eq. 6)."
    );
    Ok(())
}
