//! Quickstart: the library in five minutes, through `abws::api`.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! 1. Evaluate the variance retention ratio of an accumulation.
//! 2. Ask the (memoized) solver for the minimum accumulator width.
//! 3. Check the answer against the bit-accurate Monte-Carlo simulator.
//! 4. Predict a whole network's Table-1 row with one `AdvisorRequest`.

use abws::api::{cache, AdvisorRequest, PrecisionPolicy};
use abws::mc::{empirical_vrr, McConfig};
use abws::vrr::variance_lost::{is_suitable, log_variance_lost};

fn main() -> anyhow::Result<()> {
    // 1. A dot product of length 65,536 with (1,5,2) inputs (m_p = 5)
    //    accumulated at m_acc = 10 mantissa bits: how much variance
    //    survives? One PrecisionPolicy describes the whole setup.
    let policy = PrecisionPolicy::paper();
    let (m_acc, n) = (10, 65_536);
    let spec = policy.accum_spec(n, 1.0);
    let v = cache::vrr(&spec, m_acc);
    println!("VRR(m_acc={m_acc}, m_p={}, n={n}) = {v:.6}", policy.m_p);
    println!(
        "log v(n) = {:.2}  (suitable: {})",
        log_variance_lost(v, n),
        is_suitable(v, n)
    );

    // 2. So what is the minimum suitable width? And with chunk-64
    //    accumulation? Both queries hit the process-wide solve cache, so
    //    asking again later is free.
    let plain = cache::min_m_acc(&spec);
    let chunked = cache::min_m_acc(&spec.with_chunk(64));
    println!("minimum m_acc: {plain} (normal), {chunked} (chunk-64)");

    // 3. Trust but verify: measure the variance retention empirically
    //    with the bit-accurate reduced-precision simulator.
    for m in [plain - 2, plain] {
        let r = empirical_vrr(&McConfig::new(n, m).with_trials(64))?;
        println!(
            "measured VRR at m_acc={m}: {:.4} (theory {:.4})",
            r.vrr,
            cache::vrr(&spec, m)
        );
    }

    // 4. The paper's Table 1 for ImageNet ResNet-18, as one typed
    //    request — the same path `abws predict` and `abws serve` use.
    let report = AdvisorRequest::builtin("resnet18", policy).run()?;
    println!("\n{}", report.render());
    Ok(())
}
