//! Quickstart: the library in five minutes.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! 1. Evaluate the variance retention ratio of an accumulation.
//! 2. Ask the solver for the minimum accumulator mantissa width.
//! 3. Check the answer against the bit-accurate Monte-Carlo simulator.
//! 4. Predict a whole network's Table-1 row.

use abws::mc::{empirical_vrr, McConfig};
use abws::nets::nzr::NzrModel;
use abws::nets::predict::predict_network;
use abws::nets::resnet::resnet18_imagenet;
use abws::vrr::solver::{min_m_acc, AccumSpec};
use abws::vrr::theorem::vrr;
use abws::vrr::variance_lost::{is_suitable, log_variance_lost};

fn main() {
    // 1. A dot product of length 65,536 with (1,5,2) inputs (m_p = 5)
    //    accumulated at m_acc = 10 mantissa bits: how much variance
    //    survives?
    let (m_acc, m_p, n) = (10, 5, 65_536);
    let v = vrr(m_acc, m_p, n);
    println!("VRR(m_acc={m_acc}, m_p={m_p}, n={n}) = {v:.6}");
    println!(
        "log v(n) = {:.2}  (suitable: {})",
        log_variance_lost(v, n),
        is_suitable(v, n)
    );

    // 2. So what is the minimum suitable width? And with chunk-64
    //    accumulation?
    let spec = AccumSpec::plain(n);
    let plain = min_m_acc(&spec);
    let chunked = min_m_acc(&spec.with_chunk(64));
    println!("minimum m_acc: {plain} (normal), {chunked} (chunk-64)");

    // 3. Trust but verify: measure the variance retention empirically
    //    with the bit-accurate reduced-precision simulator.
    for m in [plain - 2, plain] {
        let r = empirical_vrr(&McConfig::new(n, m).with_trials(64));
        println!(
            "measured VRR at m_acc={m}: {:.4} (theory {:.4})",
            r.vrr,
            vrr(m, m_p, n)
        );
    }

    // 4. The paper's Table 1 for ImageNet ResNet-18.
    let pred = predict_network(&resnet18_imagenet(), &NzrModel::resnet_default(), 5, 64);
    println!("\n{}", pred.render());
}
