//! End-to-end driver (EXPERIMENTS.md §E2E): train the AOT-compiled model
//! on the synthetic classification workload through the full three-layer
//! stack — Pallas kernel → JAX train step → HLO artifact → Rust PJRT
//! runtime — at the baseline, the predicted precision, and one bit below
//! it, logging loss curves and the final-accuracy comparison.
//!
//! ```sh
//! make artifacts && cargo run --release --example train_fp8 -- --steps 300
//! ```
//!
//! Results land in `results/train_fp8.{json,csv}`.

use abws::coordinator::experiment::{ExperimentResult, ResultSink};
use abws::data::synth::{generate, SynthSpec};
use abws::runtime::{ArtifactStore, Runtime, TrainStepExecutor};
use abws::trainer::native::{NativeTrainer, PrecisionPlan, TrainConfig};
use abws::util::argparse::Args;
use abws::util::json::Json;
use abws::vrr::solver::{min_m_acc, AccumSpec};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 300);
    let seed = args.get_i64("seed", 42) as u64;

    let store = ArtifactStore::open(args.get_or("artifacts", "artifacts"))?;
    store.verify()?;
    let d = store.dims;
    println!(
        "artifacts: batch={} dim={} hidden={} classes={} ({} variants)",
        d.batch,
        d.dim,
        d.hidden,
        d.classes,
        store.variants.len()
    );

    // The model's binding accumulation is the FWD GEMM over `dim`.
    let predicted = min_m_acc(&AccumSpec::plain(d.dim));
    let below = predicted.saturating_sub(1).max(4);
    println!("predicted m_acc for n={}: {predicted} (PP-1: {below})", d.dim);

    // Pick the artifact variants closest to the prediction ladder.
    let pick = |target: u32| -> String {
        let mut best: Option<(u32, String)> = None;
        for name in store.variants.keys() {
            if let Some(m) = name
                .strip_prefix("macc")
                .and_then(|s| s.split('_').next())
                .and_then(|s| s.parse::<u32>().ok())
            {
                if name.contains("chunk") {
                    continue;
                }
                let d = m.abs_diff(target);
                if best.as_ref().map(|(bd, _)| d < *bd).unwrap_or(true) {
                    best = Some((d, name.clone()));
                }
            }
        }
        best.expect("no macc variants in artifact store").1
    };
    let variants = vec![
        ("baseline".to_string(), "full-precision accumulation"),
        (pick(predicted), "predicted precision (PP=0)"),
        (pick(below), "one bit below (PP=-1)"),
    ];

    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let (train, test) = generate(&SynthSpec {
        dim: d.dim,
        classes: d.classes,
        noise: args.get_f64("noise", 1.4),
        seed: args.get_i64("data-seed", 1234) as u64,
        ..Default::default()
    });

    let mut result = ExperimentResult::new("train_fp8");
    for (variant, label) in &variants {
        let t0 = std::time::Instant::now();
        let mut exec = TrainStepExecutor::new(&rt, &store, variant, seed)?;
        let metrics = exec.train(&train, steps)?;
        let wall = t0.elapsed();

        // Evaluate on the held-out set with the trained parameters.
        let (w1, w2) = exec.params()?;
        let cfg = TrainConfig {
            hidden: d.hidden,
            batch: d.batch,
            ..Default::default()
        };
        let mut evaluator =
            NativeTrainer::new(d.dim, d.classes, PrecisionPlan::baseline(), cfg);
        evaluator.w1 = w1;
        evaluator.w2 = w2;
        let test_acc = evaluator.evaluate(&test);

        let steps_run = metrics.steps.len();
        let sps = steps_run as f64 / wall.as_secs_f64();
        println!(
            "{variant:<16} [{label}] final-loss {:>8.4}  test-acc {:>6.3}  \
             diverged {}  ({steps_run} steps, {sps:.1} steps/s)",
            metrics.tail_loss(20).unwrap_or(f64::NAN),
            test_acc,
            metrics.diverged,
        );
        result.push_row(&[
            ("variant", Json::from(variant.as_str())),
            ("label", Json::from(*label)),
            ("final_loss", Json::from(metrics.tail_loss(20).unwrap_or(f64::NAN))),
            ("test_acc", Json::from(test_acc)),
            ("diverged", Json::from(metrics.diverged)),
            ("steps_per_sec", Json::from(sps)),
            ("loss_curve", metrics.to_json().get("loss").unwrap().clone()),
        ]);
    }

    let sink = ResultSink::new("results")?;
    sink.write(&result)?;
    println!("wrote results/train_fp8.json");
    Ok(())
}
