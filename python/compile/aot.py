"""AOT lowering — the only build-time entry point (`make artifacts`).

Lowers the train step (model.py) for a set of precision variants to HLO
**text** artifacts the Rust runtime loads via PJRT, plus a standalone
rp-GEMM kernel artifact, a manifest.json describing them, and the VRR
golden file for the cross-language formula test.

HLO text (not ``.serialize()``): jax ≥ 0.5 emits HloModuleProto with
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.rp_gemm import rp_matmul
from .model import ModelConfig, PrecisionPlan, example_args, make_train_step
from . import vrr as vrr_py


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def default_variants(cfg: ModelConfig) -> dict[str, PrecisionPlan]:
    """The artifact set: baseline + predicted ± PP, normal and chunked.

    The model's accumulation lengths are FWD: dim/hidden, BWD: classes,
    GRAD: batch. With dim=256 the binding length is the FWD dim — the Rust
    side solves for exact minima; here we bake a ladder wide enough to
    cover PP ∈ {+1, 0, −1, −2} around any prediction for these dims.
    """
    variants: dict[str, PrecisionPlan] = {"baseline": PrecisionPlan.baseline()}
    for m_acc in (4, 5, 6, 7, 8, 10, 12):
        # chunk=1 → strictly sequential partial sums (the paper's "normal
        # accumulation"); chunk=64 → the chunk-based accumulation arm.
        variants[f"macc{m_acc}"] = PrecisionPlan.uniform(m_acc, chunk=1)
        variants[f"macc{m_acc}_chunk64"] = PrecisionPlan.uniform(m_acc, chunk=64)
    return variants


def lower_variant(name: str, plan: PrecisionPlan, cfg: ModelConfig, out_dir: str) -> str:
    step = make_train_step(plan, cfg)
    lowered = jax.jit(step).lower(*example_args(cfg))
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"train_step_{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return path


def lower_kernel_artifact(cfg: ModelConfig, out_dir: str) -> str:
    """Standalone rp-GEMM artifact (runtime kernel smoke tests)."""
    def fn(a, b):
        return rp_matmul(a, b, m_acc=8, chunk=64)

    spec_a = jax.ShapeDtypeStruct((8, 256), jnp.float32)
    spec_b = jax.ShapeDtypeStruct((256, 8), jnp.float32)
    lowered = jax.jit(fn).lower(spec_a, spec_b)
    path = os.path.join(out_dir, "rp_gemm_macc8_chunk64.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    return path


def write_vrr_golden(repo_root: str) -> str:
    golden_dir = os.path.join(repo_root, "tests", "golden")
    os.makedirs(golden_dir, exist_ok=True)
    path = os.path.join(golden_dir, "vrr_golden.json")
    with open(path, "w") as f:
        json.dump({"cases": vrr_py.golden_grid()}, f, indent=1)
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--classes", type=int, default=10)
    args = ap.parse_args()

    cfg = ModelConfig(batch=args.batch, dim=args.dim, hidden=args.hidden,
                      classes=args.classes)
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)

    variants = default_variants(cfg)
    for name, plan in variants.items():
        path = lower_variant(name, plan, cfg, out_dir)
        print(f"wrote {path}")
    kpath = lower_kernel_artifact(cfg, out_dir)
    print(f"wrote {kpath}")

    manifest = {
        "batch": cfg.batch,
        "dim": cfg.dim,
        "hidden": cfg.hidden,
        "classes": cfg.classes,
        "variants": sorted(variants.keys()),
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {out_dir}/manifest.json ({len(variants)} variants)")

    repo_root = os.path.dirname(os.path.abspath(out_dir))
    print(f"wrote {write_vrr_golden(repo_root)}")


if __name__ == "__main__":
    main()
