"""Mantissa fake-quantization in JAX — the L1 primitive.

Rounds f32 values to a custom (1, e, m) floating-point format with
round-to-nearest-even, IEEE-style exponent clamping, gradual underflow and
saturating overflow — the same semantics as the Rust softfloat simulator
(rust/src/softfloat/quant.rs), which the cross-language tests pin down.

The implementation uses ``jnp.frexp`` to get the *exact* binary exponent
(log2-based exponent extraction is wrong on binade boundaries), then
scales so the target quantum is 1.0, rounds half-to-even (``jnp.round``),
and scales back. All ops are elementwise VPU-friendly primitives, so the
function can be used inside Pallas kernels and lowers to plain HLO.
"""

from __future__ import annotations

import jax.numpy as jnp


def fmt_constants(e_bits: int, m_bits: int):
    """IEEE-style derived constants of a (1, e, m) format."""
    bias = (1 << (e_bits - 1)) - 1
    e_max = bias
    e_min = 1 - bias
    max_finite = (2.0 - 2.0 ** (-m_bits)) * (2.0 ** e_max)
    return bias, e_min, e_max, max_finite


def quantize(x, m_bits: int, e_bits: int = 6):
    """Quantize ``x`` (f32 tensor) to the (1, e_bits, m_bits) format.

    Semantics (mirrors rust/src/softfloat/quant.rs::quantize, RNE mode):
      * zero / non-finite values pass through;
      * round-to-nearest-even on the mantissa at the value's own binade;
      * gradual underflow: quantum freezes at ``2^(e_min - m)`` below the
        normal range (values under half the smallest subnormal flush to 0);
      * overflow *saturates* to ±max_finite (the training-friendly choice;
        the Rust simulator returns ±inf under RNE — divergence detection
        treats both identically, and the AOT model must avoid inf
        poisoning whole tensors).
    """
    _, e_min, _, max_finite = fmt_constants(e_bits, m_bits)
    x = jnp.asarray(x, jnp.float32)

    # Input envelope: f32-subnormal inputs (|x| < 2^-126) flush to ±0.
    # They sit below every simulated format's subnormal range except the
    # (1,8,23) f32-replica (a documented envelope limit — jax's frexp and
    # ldexp do not handle f32 subnormals), and keeping them would produce
    # wrong exponents downstream.
    x = jnp.where(jnp.abs(x) < jnp.float32(2.0 ** -126), x * 0.0, x)

    # Exact exponent: frexp returns mant in [0.5, 1), exp with x = mant*2^exp,
    # so floor(log2|x|) = exp - 1.
    _, raw_exp = jnp.frexp(jnp.where(x == 0, 1.0, x))
    e = raw_exp.astype(jnp.int32) - 1
    # Quantum exponent, frozen in the subnormal range.
    q_exp = jnp.where(e < e_min, e_min - m_bits, e - m_bits)

    # Scale so the quantum is 1.0, round half-to-even, scale back.
    # ldexp (not exp2: the f32 exp2 polynomial is off by an ulp even at
    # integer arguments), staged through 2^64 because jax's ldexp neither
    # accepts nor produces f32 subnormals in one hop. The up-scaled value
    # is ≤ 2^(m+1), so both stages are exact.
    scaled = jnp.ldexp(jnp.ldexp(x, 64), -q_exp - 64)
    rounded = jnp.round(scaled)  # numpy semantics: round-half-to-even
    # Down-scale: the last multiply may legitimately round into an f32
    # subnormal (only when simulating f32-wide formats) — a single
    # correctly-rounded multiply.
    y = jnp.ldexp(rounded, q_exp + 64) * jnp.float32(2.0 ** -64)

    # Saturating overflow.
    y = jnp.clip(y, -max_finite, max_finite)
    # Zeros and non-finite inputs pass through.
    y = jnp.where(x == 0, x, y)
    y = jnp.where(jnp.isfinite(x), y, x)
    return y


def quantize_fp8_152(x):
    """The paper's representation format (1,5,2) for inputs."""
    return quantize(x, m_bits=2, e_bits=5)


def quantize_product(x, m_p: int = 5):
    """Product-term format: m_p mantissa bits, 6 exponent bits
    (products of two (1,5,2) values are exact at m_p = 5)."""
    return quantize(x, m_bits=m_p, e_bits=6)


def quantize_acc(x, m_acc: int, e_acc: int = 6):
    """Accumulator format (1, 6, m_acc) — the paper's partial-sum width."""
    return quantize(x, m_bits=m_acc, e_bits=e_acc)
