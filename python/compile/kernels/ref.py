"""Pure-jnp oracle for the reduced-precision GEMM — the correctness
reference the Pallas kernel is tested against (pytest, hypothesis).

Implements the identical chunked accumulation semantics with an explicit
``lax.scan`` over chunks (no Pallas machinery), plus an f64 "ideal"
reference for wide-accumulator sanity checks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .quant import quantize_acc, quantize_fp8_152


def rp_matmul_ref(a, b, *, m_acc: int, chunk: int = 64, e_acc: int = 6,
                  quantize_inputs: bool = True):
    """Reference chunked reduced-precision matmul (same semantics as
    rp_gemm.rp_matmul, different machinery)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    chunk = min(chunk, k)
    assert k % chunk == 0
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    if quantize_inputs:
        a = quantize_fp8_152(a)
        b = quantize_fp8_152(b)

    steps = k // chunk
    # [steps, M, chunk] and [steps, chunk, N] chunk stacks.
    a_chunks = a.reshape(m, steps, chunk).transpose(1, 0, 2)
    b_chunks = b.reshape(steps, chunk, n)

    def body(acc, ab):
        a_blk, b_blk = ab
        chunk_sum = quantize_acc(
            jnp.dot(a_blk, b_blk, preferred_element_type=jnp.float32),
            m_acc, e_acc,
        )
        return quantize_acc(acc + chunk_sum, m_acc, e_acc), None

    init = jnp.zeros((m, n), jnp.float32)
    out, _ = jax.lax.scan(body, init, (a_chunks, b_chunks))
    return out


def ideal_matmul(a, b, *, quantize_inputs: bool = True):
    """Ideal (f32, effectively exact for these magnitudes) accumulation of
    the optionally fp8-quantized operands — the 'full precision
    accumulation' baseline arm."""
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    if quantize_inputs:
        a = quantize_fp8_152(a)
        b = quantize_fp8_152(b)
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def sequential_sum_ref(terms, *, m_acc: int, e_acc: int = 6):
    """Strictly sequential reduced-precision sum of a 1-D term vector —
    mirrors rust softfloat::accumulate::sequential_sum for cross-language
    spot checks."""
    def body(acc, t):
        return quantize_acc(acc + t, m_acc, e_acc), None

    out, _ = jax.lax.scan(body, jnp.float32(0.0), jnp.asarray(terms, jnp.float32))
    return out
