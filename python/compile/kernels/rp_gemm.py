"""Reduced-precision-accumulation GEMM as a Pallas kernel — Layer 1.

The paper's hardware model: products at ``m_p`` mantissa bits feed an
accumulator that rounds every partial sum to ``m_acc`` bits; optionally a
two-level *chunked* accumulation (Wang et al. 2018, paper §4.2).

TPU adaptation (DESIGN.md §Hardware-Adaptation): the K dimension is tiled
into chunks by the Pallas grid; each grid step computes one chunk's
partial sum with an MXU-shaped ``jnp.dot`` (the wide intra-chunk adder
tree of a hardware chunked accumulator), rounds it to the accumulator
format, and folds it into a running VMEM accumulator that is re-rounded
after every chunk — exactly Corollary 1's structure. ``chunk=1``
degenerates to the fully sequential accumulation of Lemma 1/Theorem 1
(every single product rounded into the running sum).

Pallas runs with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so the kernel lowers to plain HLO; on a real TPU the
same BlockSpec schedule maps chunks to MXU passes with the accumulator in
VMEM scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quant import quantize_acc, quantize_fp8_152, quantize_product


def _rp_matmul_kernel(a_ref, b_ref, o_ref, *, m_acc: int, e_acc: int, m_p: int,
                      quantize_inputs: bool):
    """One grid step: fold chunk ``k`` of the K dimension into the output.

    The output block is revisited by every grid step (same index map), so
    it serves as the inter-chunk accumulator carried across steps.
    """
    k = pl.program_id(0)

    a_blk = a_ref[...].astype(jnp.float32)  # [M, chunk]
    b_blk = b_ref[...].astype(jnp.float32)  # [chunk, N]
    if quantize_inputs:
        a_blk = quantize_fp8_152(a_blk)
        b_blk = quantize_fp8_152(b_blk)

    # Intra-chunk: MXU pass. Products are exact at m_p bits for (1,5,2)
    # inputs; the chunk partial sum is rounded to the accumulator format
    # (the hardware chunk adder's output register).
    chunk_sum = jnp.dot(a_blk, b_blk, preferred_element_type=jnp.float32)
    chunk_sum = quantize_acc(chunk_sum, m_acc, e_acc)
    del m_p  # products exact for fp8 inputs; kept in the signature for ablations

    # Inter-chunk: running accumulator re-rounded after every addition —
    # this is where swamping lives.
    prev = jnp.where(k == 0, jnp.zeros_like(o_ref[...]), o_ref[...])
    o_ref[...] = quantize_acc(prev + chunk_sum, m_acc, e_acc)


def rp_matmul(a, b, *, m_acc: int, chunk: int = 64, e_acc: int = 6, m_p: int = 5,
              quantize_inputs: bool = True, interpret: bool = True):
    """Reduced-precision-accumulation matmul ``a @ b``.

    a: [M, K] f32, b: [K, N] f32; K must be divisible by ``chunk``
    (callers pad or pick dims accordingly — model.py uses powers of two).
    Returns [M, N] f32 whose every element went through the chunked
    reduced-precision accumulation.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    # A chunk longer than K degenerates to a single intra-chunk pass.
    chunk = min(chunk, k)
    assert k % chunk == 0, f"K={k} not divisible by chunk={chunk}"
    steps = k // chunk

    kernel = functools.partial(
        _rp_matmul_kernel,
        m_acc=m_acc,
        e_acc=e_acc,
        m_p=m_p,
        quantize_inputs=quantize_inputs,
    )
    return pl.pallas_call(
        kernel,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((m, chunk), lambda i: (0, i)),
            pl.BlockSpec((chunk, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((m, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)


def baseline_matmul(a, b, *, quantize_inputs: bool = True):
    """The paper's control arm: same (1,5,2) representation quantization,
    ideal (f32) accumulation."""
    if quantize_inputs:
        a = quantize_fp8_152(a)
        b = quantize_fp8_152(b)
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


__all__ = ["rp_matmul", "baseline_matmul", "quantize_product"]
