"""Layer 2 — the JAX model: a two-layer MLP classifier whose three GEMMs
(FWD, BWD, GRAD; paper Fig. 2) each run through the reduced-precision
accumulation kernel at their own precision, with explicit backward passes
(mirroring rust/src/trainer/native.rs operation-for-operation).

The train step is a pure function
``(w1, w2, m1, m2, x, y) -> (w1', w2', m1', m2', loss, acc)``
so the Rust runtime can carry the state as PJRT literals. Lowered once by
aot.py; never executed from Python at run time.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from .kernels.rp_gemm import baseline_matmul, rp_matmul


@dataclass(frozen=True)
class GemmPrecision:
    """Accumulation precision of one GEMM (None m_acc = ideal/baseline)."""

    m_acc: Optional[int]
    chunk: int = 64

    def matmul(self, a, b):
        if self.m_acc is None:
            return baseline_matmul(a, b)
        # chunk=1 gives the strictly sequential accumulation; the kernel
        # requires K % chunk == 0, which holds for the power-of-two dims
        # the artifacts are lowered with.
        return rp_matmul(a, b, m_acc=self.m_acc, chunk=self.chunk)


@dataclass(frozen=True)
class PrecisionPlan:
    """Per-GEMM accumulation precision (the Table-1 unit)."""

    fwd: GemmPrecision
    bwd: GemmPrecision
    grad: GemmPrecision

    @staticmethod
    def baseline() -> "PrecisionPlan":
        none = GemmPrecision(m_acc=None)
        return PrecisionPlan(none, none, none)

    @staticmethod
    def uniform(m_acc: int, chunk: int = 64) -> "PrecisionPlan":
        g = GemmPrecision(m_acc=m_acc, chunk=chunk)
        return PrecisionPlan(g, g, g)

    @staticmethod
    def per_gemm(fwd: int, bwd: int, grad: int, chunk: int = 64) -> "PrecisionPlan":
        return PrecisionPlan(
            GemmPrecision(fwd, chunk), GemmPrecision(bwd, chunk), GemmPrecision(grad, chunk)
        )


@dataclass(frozen=True)
class ModelConfig:
    batch: int = 32
    dim: int = 256
    hidden: int = 64
    classes: int = 10
    lr: float = 0.05
    momentum: float = 0.9
    loss_scale: float = 1000.0


def forward(plan: PrecisionPlan, w1, w2, x):
    """FWD GEMMs; returns (h_pre, h, logits)."""
    h_pre = plan.fwd.matmul(x, w1)
    h = jnp.maximum(h_pre, 0.0)
    logits = plan.fwd.matmul(h, w2)
    return h_pre, h, logits


def train_step(plan: PrecisionPlan, cfg: ModelConfig, w1, w2, m1, m2, x, y):
    """One SGD-with-momentum step; explicit backward through rp GEMMs."""
    h_pre, h, logits = forward(plan, w1, w2, x)

    # Softmax cross-entropy and the scaled logits gradient.
    logits_max = jnp.max(logits, axis=1, keepdims=True)
    z = logits - logits_max
    log_probs = z - jnp.log(jnp.sum(jnp.exp(z), axis=1, keepdims=True))
    onehot = jax.nn.one_hot(y, cfg.classes, dtype=jnp.float32)
    loss = -jnp.mean(jnp.sum(onehot * log_probs, axis=1))
    acc = jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))

    probs = jnp.exp(log_probs)
    dlogits = (probs - onehot) / cfg.batch
    dlogits = dlogits * cfg.loss_scale  # loss scaling (Micikevicius 2017)

    # GRAD GEMM: dW2 = hᵀ · dlogits (accumulation across the batch).
    dw2 = plan.grad.matmul(h.T, dlogits)
    # BWD GEMM: dh = dlogits · W2ᵀ, ReLU-masked.
    dh = plan.bwd.matmul(dlogits, w2.T)
    dh = jnp.where(h_pre > 0, dh, 0.0)
    # GRAD GEMM: dW1 = xᵀ · dh.
    dw1 = plan.grad.matmul(x.T, dh)

    # SGD with momentum on unscaled gradients.
    inv = 1.0 / cfg.loss_scale
    m1n = cfg.momentum * m1 + dw1 * inv
    m2n = cfg.momentum * m2 + dw2 * inv
    w1n = w1 - cfg.lr * m1n
    w2n = w2 - cfg.lr * m2n
    return w1n, w2n, m1n, m2n, loss, acc


def make_train_step(plan: PrecisionPlan, cfg: ModelConfig):
    """Bind plan/config; returns f(w1, w2, m1, m2, x, y) -> 6-tuple."""

    def step(w1, w2, m1, m2, x, y):
        return train_step(plan, cfg, w1, w2, m1, m2, x, y)

    return step


def example_args(cfg: ModelConfig):
    """ShapeDtypeStructs matching the Rust runtime calling convention."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((cfg.dim, cfg.hidden), f32),      # w1
        jax.ShapeDtypeStruct((cfg.hidden, cfg.classes), f32),  # w2
        jax.ShapeDtypeStruct((cfg.dim, cfg.hidden), f32),      # m1
        jax.ShapeDtypeStruct((cfg.hidden, cfg.classes), f32),  # m2
        jax.ShapeDtypeStruct((cfg.batch, cfg.dim), f32),       # x
        jax.ShapeDtypeStruct((cfg.batch,), jnp.int32),         # y
    )


def init_params(cfg: ModelConfig, seed: int = 0):
    """He-initialized parameters (python-side tests only; the Rust runtime
    initializes its own state)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w1 = jax.random.normal(k1, (cfg.dim, cfg.hidden), jnp.float32) * (2.0 / cfg.dim) ** 0.5
    w2 = (
        jax.random.normal(k2, (cfg.hidden, cfg.classes), jnp.float32)
        * (2.0 / cfg.hidden) ** 0.5
    )
    m1 = jnp.zeros_like(w1)
    m2 = jnp.zeros_like(w2)
    return w1, w2, m1, m2
