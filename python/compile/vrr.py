"""Python mirror of the VRR analysis (paper Eqs. 1-6).

Kept deliberately independent of the Rust implementation
(rust/src/vrr/): same formulas, different code — the golden-file test
(tests/golden/vrr_golden.json, checked by both pytest and `cargo test`)
pins the two down against each other.
"""

from __future__ import annotations

import math


def two_q(x: float) -> float:
    """2·Q(x) = P[|N(0,1)| > x] = erfc(x/√2)."""
    return math.erfc(x / math.sqrt(2.0))


def tail_prob(threshold_log2: float, i: float) -> float:
    """2Q(2^threshold / √i)."""
    return two_q(2.0 ** threshold_log2 / math.sqrt(i))


def vrr_full_swamping(m_acc: int, n: int) -> float:
    """Lemma 1 (Eq. 1)."""
    if n <= 2:
        return 1.0
    num = 0.0
    k = 0.0
    tail_prev = tail_prob(m_acc, 1.0)
    for i in range(2, n):
        tail_now = tail_prob(m_acc, float(i))
        q_i = tail_now * (1.0 - tail_prev)
        num += i * q_i
        k += q_i
        tail_prev = tail_now
    q_tilde = 1.0 - tail_prob(m_acc, float(n))
    num += n * q_tilde
    k += q_tilde
    if k == 0.0:
        return 0.0
    return num / (k * n)


def _stage_loss_sum(upto: int) -> float:
    return sum(2.0 ** j * (2.0 ** j - 1.0) * (2.0 ** (j + 1) - 1.0)
               for j in range(1, upto + 1))


def alpha(m_acc: int, m_p: int, stages: int) -> float:
    return 2.0 ** (m_acc - 3 * m_p) / 3.0 * _stage_loss_sum(stages)


def vrr(m_acc: int, m_p: int, n: int) -> float:
    """Theorem 1 (Eq. 2)."""
    if n <= 2:
        return 1.0
    nf = float(n)

    a_full = alpha(m_acc, m_p, m_p)
    term1 = 0.0
    k1 = 0.0
    start = n if a_full >= n - 1 else max(int(math.floor(a_full)) + 1, 2)
    if start < n:
        tail_prev = tail_prob(m_acc, float(start - 1))
        for i in range(start, n):
            tail_now = tail_prob(m_acc, float(i))
            q_i = tail_now * (1.0 - tail_prev)
            term1 += (i - a_full) * q_i
            k1 += q_i
            tail_prev = tail_now

    term2 = 0.0
    k2 = 0.0
    for j_r in range(2, m_p + 1):
        a_jr = alpha(m_acc, m_p, j_r - 1)
        if nf <= a_jr:
            continue
        n_prev = 2.0 ** (m_acc - m_p + j_r)
        lo = tail_prob(m_acc - m_p + j_r - 1, nf)
        hi = tail_prob(m_acc - m_p + j_r, nf)
        q_jr = n_prev * lo * (1.0 - hi)
        term2 += (nf - a_jr) * q_jr
        k2 += q_jr

    k3 = 1.0 - tail_prob(m_acc - m_p + 1, nf)
    k = k1 + k2 + k3
    if k == 0.0:
        return 0.0
    return min(max((term1 + term2 + nf * k3) / (k * nf), 0.0), 1.0)


def interchunk_m_p(m_acc: int, m_p: int, n1: int) -> int:
    growth = int(round(math.log2(max(n1, 1))))
    return min(m_p + growth, m_acc)


def vrr_chunked(m_acc: int, m_p: int, n1: int, n2: int) -> float:
    """Corollary 1 (Eq. 3)."""
    return vrr(m_acc, m_p, n1) * vrr(m_acc, interchunk_m_p(m_acc, m_p, n1), n2)


def log_variance_lost(vrr_value: float, n: int) -> float:
    """log v(n) = n (1 - VRR)  (Eq. 6 in log space)."""
    return n * (1.0 - vrr_value)


CUTOFF_LN = math.log(50.0)


def is_suitable(vrr_value: float, n: int) -> bool:
    return log_variance_lost(vrr_value, n) < CUTOFF_LN


def golden_grid():
    """The (m_acc, m_p, n) grid pinned by tests/golden/vrr_golden.json."""
    cases = []
    for m_acc in (4, 6, 8, 10, 12, 15):
        for n in (16, 256, 4096, 65536, 1048576):
            cases.append({
                "m_acc": m_acc,
                "m_p": 5,
                "n": n,
                "vrr": vrr(m_acc, 5, n),
                "vrr_full": vrr_full_swamping(m_acc, n),
                "vrr_chunked64": vrr_chunked(m_acc, 5, 64, max(n // 64, 1)),
            })
    return cases
