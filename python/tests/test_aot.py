"""AOT pipeline tests: HLO-text lowering shape, manifest consistency,
and variant coverage — the contract the Rust runtime relies on."""

import json
import os

import jax
import pytest

from compile.aot import default_variants, to_hlo_text
from compile.model import ModelConfig, PrecisionPlan, example_args, make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestLoweringContract:
    def test_hlo_text_shape(self):
        cfg = ModelConfig(batch=8, dim=32, hidden=16, classes=4)
        step = make_train_step(PrecisionPlan.uniform(8, chunk=16), cfg)
        text = to_hlo_text(jax.jit(step).lower(*example_args(cfg)))
        # The Rust loader parses HLO text: must be a module with an ENTRY.
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        # Six inputs (w1 w2 m1 m2 x y), tuple output with six leaves.
        assert text.count("parameter(") >= 6

    def test_default_variants_cover_the_pp_ladder(self):
        cfg = ModelConfig()
        variants = default_variants(cfg)
        assert "baseline" in variants
        # Normal and chunked arm for every m_acc in the ladder.
        for m in (4, 5, 6, 7, 8, 10, 12):
            assert f"macc{m}" in variants
            assert f"macc{m}_chunk64" in variants


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACT_DIR, "manifest.json")),
    reason="artifacts not generated (run `make artifacts`)",
)
class TestGeneratedArtifacts:
    def manifest(self):
        with open(os.path.join(ARTIFACT_DIR, "manifest.json")) as f:
            return json.load(f)

    def test_manifest_lists_existing_files(self):
        man = self.manifest()
        assert man["variants"], "no variants in manifest"
        for name in man["variants"]:
            path = os.path.join(ARTIFACT_DIR, f"train_step_{name}.hlo.txt")
            assert os.path.exists(path), f"missing artifact {path}"

    def test_manifest_dims_are_positive(self):
        man = self.manifest()
        for key in ("batch", "dim", "hidden", "classes"):
            assert man[key] > 0

    def test_artifacts_are_hlo_text(self):
        man = self.manifest()
        for name in man["variants"][:3]:
            path = os.path.join(ARTIFACT_DIR, f"train_step_{name}.hlo.txt")
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), f"{name}: {head!r}"
