"""The CORE L1 correctness signal: the Pallas kernel vs the pure-jnp
oracle, exact equality, across hypothesis-driven shape/precision sweeps,
plus physical checks (variance loss, chunking recovery) on larger sizes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import ideal_matmul, rp_matmul_ref, sequential_sum_ref
from compile.kernels.rp_gemm import baseline_matmul, rp_matmul


def randn(rng, *shape):
    return rng.normal(size=shape).astype(np.float32)


class TestKernelVsOracle:
    @settings(max_examples=40, deadline=None)
    @given(
        m=st.integers(1, 9),
        n=st.integers(1, 9),
        steps=st.integers(1, 8),
        chunk=st.sampled_from([1, 2, 4, 8, 16]),
        m_acc=st.sampled_from([3, 5, 6, 8, 10, 12, 16]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_exact_match_random_shapes(self, m, n, steps, chunk, m_acc, seed):
        rng = np.random.default_rng(seed)
        k = steps * chunk
        a, b = randn(rng, m, k), randn(rng, k, n)
        got = rp_matmul(a, b, m_acc=m_acc, chunk=chunk)
        want = rp_matmul_ref(a, b, m_acc=m_acc, chunk=chunk)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(max_examples=15, deadline=None)
    @given(
        m_acc=st.sampled_from([4, 6, 8]),
        scale=st.sampled_from([0.01, 1.0, 100.0]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_exact_match_across_scales(self, m_acc, scale, seed):
        # Dynamic range matters for swamping — sweep operand scales.
        rng = np.random.default_rng(seed)
        a = randn(rng, 4, 128) * scale
        b = randn(rng, 128, 4) * scale
        got = rp_matmul(a, b, m_acc=m_acc, chunk=16)
        want = rp_matmul_ref(a, b, m_acc=m_acc, chunk=16)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_sequential_chunk1_equals_scan(self):
        # chunk=1 is the strictly sequential accumulation; cross-check one
        # output element against the 1-D sequential reference.
        rng = np.random.default_rng(7)
        a, b = randn(rng, 1, 64), randn(rng, 64, 1)
        got = rp_matmul(a, b, m_acc=6, chunk=1)[0, 0]
        from compile.kernels.quant import quantize_fp8_152
        terms = (
            np.asarray(quantize_fp8_152(jnp.asarray(a[0])))
            * np.asarray(quantize_fp8_152(jnp.asarray(b[:, 0])))
        )
        want = sequential_sum_ref(terms, m_acc=6)
        assert float(got) == float(want)

    def test_oversized_chunk_degenerates(self):
        rng = np.random.default_rng(9)
        a, b = randn(rng, 3, 32), randn(rng, 32, 3)
        big = rp_matmul(a, b, m_acc=8, chunk=512)
        exact = rp_matmul(a, b, m_acc=8, chunk=32)
        np.testing.assert_array_equal(np.asarray(big), np.asarray(exact))


class TestPhysicalBehaviour:
    def test_wide_accumulator_matches_ideal(self):
        rng = np.random.default_rng(1)
        a, b = randn(rng, 8, 256), randn(rng, 256, 8)
        got = rp_matmul(a, b, m_acc=22, chunk=64)
        want = ideal_matmul(a, b)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_narrow_accumulator_loses_variance(self):
        rng = np.random.default_rng(2)
        k = 8192
        a, b = randn(rng, 8, k), randn(rng, k, 8)
        narrow = np.asarray(rp_matmul(a, b, m_acc=4, chunk=1))
        ideal = np.asarray(ideal_matmul(a, b))
        assert narrow.var() < 0.8 * ideal.var(), (narrow.var(), ideal.var())

    def test_chunking_recovers_variance(self):
        rng = np.random.default_rng(3)
        k = 8192
        a, b = randn(rng, 8, k), randn(rng, k, 8)
        seq = np.asarray(rp_matmul(a, b, m_acc=4, chunk=1))
        chunked = np.asarray(rp_matmul(a, b, m_acc=4, chunk=64))
        ideal = np.asarray(ideal_matmul(a, b))
        assert chunked.var() > seq.var()
        assert chunked.var() > 0.7 * ideal.var()

    def test_baseline_is_fp8_repr_with_ideal_acc(self):
        rng = np.random.default_rng(4)
        a, b = randn(rng, 4, 64), randn(rng, 64, 4)
        got = np.asarray(baseline_matmul(a, b))
        want = np.asarray(ideal_matmul(a, b))
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_quantize_inputs_flag(self):
        rng = np.random.default_rng(5)
        a, b = randn(rng, 4, 64), randn(rng, 64, 4)
        raw = np.asarray(rp_matmul(a, b, m_acc=20, chunk=64, quantize_inputs=False))
        f32 = a @ b
        np.testing.assert_allclose(raw, f32, rtol=1e-4, atol=1e-5)

    def test_dim_mismatch_raises(self):
        with pytest.raises(AssertionError):
            rp_matmul(np.zeros((2, 8), np.float32), np.zeros((4, 2), np.float32), m_acc=8)
