"""L2 model tests: train-step shapes, convergence at adequate precision,
divergence/degradation at starved precision, loss-scaling plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    GemmPrecision,
    ModelConfig,
    PrecisionPlan,
    example_args,
    forward,
    init_params,
    make_train_step,
)


CFG = ModelConfig(batch=16, dim=64, hidden=32, classes=4)


def synth_batch(cfg, seed=0, noise=1.0):
    """Gaussian-mixture batch matching rust/src/data/synth.rs statistics."""
    rng = np.random.default_rng(seed)
    means = rng.normal(size=(cfg.classes, cfg.dim))
    means /= np.linalg.norm(means, axis=1, keepdims=True)
    y = rng.integers(0, cfg.classes, size=cfg.batch)
    x = means[y] + noise * rng.normal(size=(cfg.batch, cfg.dim)) / np.sqrt(cfg.dim)
    return x.astype(np.float32), y.astype(np.int32)


def run_training(plan, cfg, steps=120, seed=0, noise=1.0):
    step_fn = jax.jit(make_train_step(plan, cfg))
    w1, w2, m1, m2 = init_params(cfg, seed)
    losses, accs = [], []
    for i in range(steps):
        x, y = synth_batch(cfg, seed=1000 + (i % 8), noise=noise)
        w1, w2, m1, m2, loss, acc = step_fn(w1, w2, m1, m2, x, y)
        losses.append(float(loss))
        accs.append(float(acc))
    return losses, accs


class TestShapes:
    def test_example_args_match_calling_convention(self):
        args = example_args(CFG)
        assert args[0].shape == (CFG.dim, CFG.hidden)
        assert args[4].shape == (CFG.batch, CFG.dim)
        assert args[5].dtype == jnp.int32

    def test_train_step_output_arity_and_shapes(self):
        step = make_train_step(PrecisionPlan.baseline(), CFG)
        w1, w2, m1, m2 = init_params(CFG)
        x, y = synth_batch(CFG)
        out = step(w1, w2, m1, m2, x, y)
        assert len(out) == 6
        assert out[0].shape == w1.shape
        assert out[1].shape == w2.shape
        assert out[4].shape == ()  # loss
        assert out[5].shape == ()  # acc

    def test_forward_shapes(self):
        w1, w2, _, _ = init_params(CFG)
        x, _ = synth_batch(CFG)
        h_pre, h, logits = forward(PrecisionPlan.baseline(), w1, w2, x)
        assert h.shape == (CFG.batch, CFG.hidden)
        assert logits.shape == (CFG.batch, CFG.classes)
        assert bool(jnp.all(h >= 0))


class TestTraining:
    def test_baseline_converges(self):
        losses, accs = run_training(PrecisionPlan.baseline(), CFG)
        assert losses[-1] < 0.5 * losses[0], (losses[0], losses[-1])
        assert np.mean(accs[-20:]) > 0.8

    def test_adequate_precision_tracks_baseline(self):
        base_losses, base_accs = run_training(PrecisionPlan.baseline(), CFG)
        rp_losses, rp_accs = run_training(PrecisionPlan.uniform(12, chunk=64), CFG)
        assert np.mean(rp_accs[-20:]) > np.mean(base_accs[-20:]) - 0.1
        assert rp_losses[-1] < 0.7 * rp_losses[0]

    def test_starved_precision_degrades(self):
        # m_acc=1 on a harder task must clearly underperform the baseline.
        base_losses, _ = run_training(PrecisionPlan.baseline(), CFG, noise=2.0)
        bad_losses, _ = run_training(PrecisionPlan.uniform(1, chunk=1), CFG, noise=2.0)
        assert (
            not np.isfinite(bad_losses[-1])
            or np.mean(bad_losses[-20:]) > 1.25 * np.mean(base_losses[-20:])
        ), (np.mean(bad_losses[-20:]), np.mean(base_losses[-20:]))

    def test_momentum_state_updates(self):
        step = make_train_step(PrecisionPlan.baseline(), CFG)
        w1, w2, m1, m2 = init_params(CFG)
        x, y = synth_batch(CFG)
        _, _, m1n, m2n, _, _ = step(w1, w2, m1, m2, x, y)
        assert float(jnp.abs(m1n).max()) > 0
        assert float(jnp.abs(m2n).max()) > 0


class TestLowering:
    def test_all_plans_lower_to_hlo(self):
        from compile.aot import to_hlo_text

        for plan in [
            PrecisionPlan.baseline(),
            PrecisionPlan.uniform(8, chunk=64),
            PrecisionPlan.per_gemm(7, 5, 9, chunk=1),
        ]:
            step = make_train_step(plan, CFG)
            lowered = jax.jit(step).lower(*example_args(CFG))
            text = to_hlo_text(lowered)
            assert text.startswith("HloModule")
            assert "f32[" in text

    def test_lowered_step_runs_and_matches_eager(self):
        plan = PrecisionPlan.uniform(8, chunk=16)
        step = make_train_step(plan, CFG)
        w1, w2, m1, m2 = init_params(CFG, seed=3)
        x, y = synth_batch(CFG, seed=3)
        eager = step(w1, w2, m1, m2, x, y)
        jitted = jax.jit(step)(w1, w2, m1, m2, x, y)
        for e, j in zip(eager, jitted):
            np.testing.assert_allclose(np.asarray(e), np.asarray(j), rtol=1e-5, atol=1e-6)
