"""Unit + property tests for the mantissa fake-quantization (L1 primitive).

Hypothesis sweeps values and formats and pins the semantics shared with
the Rust simulator: idempotence, monotonicity, half-ulp error bound, RNE
tie behaviour, gradual underflow and saturating overflow.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.quant import fmt_constants, quantize, quantize_fp8_152


def q(x, m, e):
    return float(quantize(jnp.float32(x), m, e))


class TestKnownValues:
    def test_exact_values_pass_through(self):
        for m, e in [(2, 5), (5, 6), (10, 5), (23, 8)]:
            for v in [0.0, 1.0, -1.5, 0.25, 2.0]:
                assert q(v, m, e) == v

    def test_rne_ties_to_even_fp8(self):
        # (1,5,2): representable 1.0, 1.25, 1.5, 1.75.
        assert q(1.125, 2, 5) == 1.0  # tie → even (00)
        assert q(1.375, 2, 5) == 1.5  # tie → even (10)
        assert q(-1.125, 2, 5) == -1.0
        assert q(1.3, 2, 5) == 1.25
        assert q(1.97, 2, 5) == 2.0  # crosses the binade

    def test_saturating_overflow(self):
        _, _, _, max_finite = fmt_constants(5, 2)
        assert max_finite == 57344.0
        assert q(1e9, 2, 5) == max_finite
        assert q(-1e9, 2, 5) == -max_finite

    def test_gradual_underflow(self):
        # (1,5,10) = fp16: min subnormal 2^-24.
        min_sub = 2.0 ** -24
        assert q(min_sub, 10, 5) == min_sub
        assert q(0.4 * min_sub, 10, 5) == 0.0
        assert q(3.0 * min_sub, 10, 5) == 3.0 * min_sub
        assert q(3.5 * min_sub, 10, 5) == 4.0 * min_sub  # tie → even

    def test_nonfinite_pass_through(self):
        assert np.isnan(q(np.nan, 2, 5))
        assert q(np.inf, 2, 5) == np.inf
        assert q(-np.inf, 2, 5) == -np.inf


fmt_strategy = st.sampled_from([(2, 5), (3, 6), (5, 6), (7, 6), (9, 6), (10, 5), (12, 6)])
value_strategy = st.floats(
    min_value=-1e4, max_value=1e4, allow_nan=False, allow_infinity=False, width=32
)


class TestProperties:
    @settings(max_examples=300, deadline=None)
    @given(value_strategy, fmt_strategy)
    def test_idempotent(self, x, fmt):
        m, e = fmt
        once = q(x, m, e)
        assert q(once, m, e) == once

    @settings(max_examples=200, deadline=None)
    @given(value_strategy, fmt_strategy)
    def test_odd_symmetry(self, x, fmt):
        m, e = fmt
        assert q(-x, m, e) == -q(x, m, e)

    @settings(max_examples=200, deadline=None)
    @given(
        st.lists(value_strategy, min_size=2, max_size=32),
        fmt_strategy,
    )
    def test_monotone(self, xs, fmt):
        m, e = fmt
        xs = sorted(xs)
        qs = [q(x, m, e) for x in xs]
        assert all(a <= b for a, b in zip(qs, qs[1:]))

    @settings(max_examples=300, deadline=None)
    @given(
        st.floats(min_value=2.0 ** -10, max_value=1024.0, allow_nan=False, width=32),
        fmt_strategy,
    )
    def test_half_ulp_error_bound(self, x, fmt):
        m, e = fmt
        _, e_min, _, max_finite = fmt_constants(e, m)
        if x > max_finite:
            return
        got = q(x, m, e)
        ulp = 2.0 ** (max(int(np.floor(np.log2(abs(x)))), e_min) - m)
        # f32 inputs carry their own half-ulp; allow for it.
        assert abs(got - x) <= 0.5 * ulp * (1 + 1e-6) + 1e-30

    @settings(max_examples=200, deadline=None)
    @given(st.floats(min_value=-16384.0, max_value=16384.0, allow_nan=False, width=32))
    def test_wide_format_is_near_identity(self, x):
        # m=23 on f32 data: quantization must be exact (same mantissa
        # width). Inputs in f32's subnormal range are excluded — there the
        # (1,8,23) *format's* quantum is below what jax's ldexp staging
        # resolves, a documented simulator envelope limit.
        if x != 0 and abs(x) < 2.0 ** -126:
            return
        assert q(x, 23, 8) == np.float32(x)

    @settings(max_examples=100, deadline=None)
    @given(
        st.integers(min_value=2, max_value=12),
        st.floats(min_value=0.015625, max_value=128.0, allow_nan=False, width=32),
    )
    def test_more_bits_never_worse(self, m, x):
        # Error is non-increasing in mantissa width.
        err_narrow = abs(q(x, m, 6) - x)
        err_wide = abs(q(x, m + 1, 6) - x)
        assert err_wide <= err_narrow + 1e-30


class TestVectorized:
    def test_matches_scalar_on_batch(self):
        rng = np.random.default_rng(3)
        xs = rng.normal(size=(256,)).astype(np.float32) * 10
        batch = np.asarray(quantize(jnp.asarray(xs), 5, 6))
        for i in range(0, 256, 17):
            assert batch[i] == q(xs[i], 5, 6)

    def test_fp8_helper_matches_explicit(self):
        xs = jnp.asarray(np.linspace(-4, 4, 101, dtype=np.float32))
        assert bool(jnp.all(quantize_fp8_152(xs) == quantize(xs, 2, 5)))

    def test_zero_preserves_sign(self):
        out = quantize(jnp.asarray([0.0, -0.0], jnp.float32), 2, 5)
        assert float(out[0]) == 0.0
        assert float(out[1]) == 0.0
