//! Figure 1(a) regeneration (scaled down): training diverges / stalls
//! when the accumulation precision is reduced naively below the
//! requirement, while the baseline converges — the paper's motivating
//! plot, on the bit-accurate native trainer.
//!
//! The paper's y-axis is ImageNet test error over epochs; ours is the
//! synthetic-task loss/error over steps. The *shape* is the target: the
//! baseline curve descends, the naive reduced-accumulation curve does
//! not (or comes apart).

use abws::api::{baseline_plan, PrecisionPolicy};
use abws::coordinator::experiment::{ExperimentResult, ResultSink};
use abws::data::synth::{generate, SynthSpec};
use abws::trainer::native::{NativeTrainer, PrecisionPlan, TrainConfig};
use abws::util::json::Json;

fn main() {
    // FWD accumulation length = dim = 2048: the solver requires ~8 bits;
    // running at m_acc=4 is the "naive reduced accumulation" of Fig 1a.
    let dim = 2048;
    let classes = 10;
    let spec = SynthSpec {
        n_train: 1024,
        n_test: 256,
        dim,
        classes,
        noise: 1.2,
        seed: 31,
    };
    let (train, test) = generate(&spec);
    let cfg = TrainConfig {
        hidden: 48,
        steps: 120,
        batch: 24,
        seed: 7,
        log_every: 1,
        ..Default::default()
    };

    let arms: Vec<(&str, PrecisionPlan)> = vec![
        ("baseline (ideal accumulation)", baseline_plan()),
        (
            "reduced accumulation m_acc=4",
            PrecisionPolicy::paper().plan_uniform(4),
        ),
    ];

    let mut result = ExperimentResult::new("fig1a");
    let mut finals = Vec::new();
    for (label, plan) in arms {
        let mut t = NativeTrainer::new(dim, classes, plan, cfg);
        let m = t.train(&train);
        let acc = t.evaluate(&test);
        println!("--- {label} ---");
        for r in m.steps.iter().step_by(10) {
            println!("step {:>4}  loss {:>9.4}  err {:>6.3}", r.step, r.loss, 1.0 - r.train_acc);
        }
        println!(
            "final loss {:.4}, test error {:.3}, diverged {}",
            m.tail_loss(10).unwrap_or(f64::NAN),
            1.0 - acc,
            m.diverged
        );
        finals.push((label, m.tail_loss(10).unwrap_or(f64::INFINITY), 1.0 - acc, m.diverged));
        result.push_row(&[
            ("arm", Json::from(label)),
            ("final_loss", Json::from(m.tail_loss(10).unwrap_or(f64::NAN))),
            ("test_error", Json::from(1.0 - acc)),
            ("diverged", Json::from(m.diverged)),
            ("loss_curve", m.to_json().get("loss").unwrap().clone()),
        ]);
    }

    let (_, base_loss, base_err, _) = finals[0];
    let (_, red_loss, red_err, red_div) = finals[1];
    let reproduced = red_div || red_loss > 1.5 * base_loss || red_err > base_err + 0.1;
    println!(
        "\nFig 1a shape — baseline converges, naive reduced accumulation fails: {}",
        if reproduced { "REPRODUCED" } else { "NOT reproduced" }
    );
    result.note(format!(
        "baseline loss {base_loss:.4}/err {base_err:.3}; reduced loss {red_loss:.4}/err {red_err:.3}; diverged={red_div}"
    ));

    ResultSink::new("results").unwrap().write(&result).unwrap();
    println!("wrote results/fig1a.json");
}
