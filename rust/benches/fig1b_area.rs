//! Figure 1(b) regeneration: estimated FPU area versus precision
//! configuration, normalized to FP32/32 — including the paper's headline
//! "extra 1.5–2.2× area reduction" from narrowing the accumulator of a
//! reduced-precision multiplier.

use abws::coordinator::experiment::{ExperimentResult, ResultSink};
use abws::hw::fpu::{FpuAreaModel, FpuConfig};
use abws::hw::report::{area_rows, render};
use abws::softfloat::FpFormat;
use abws::util::json::Json;

fn main() {
    let model = FpuAreaModel::default();
    let rows = area_rows(&model, &FpuAreaModel::fig1b_configs());
    print!("{}", render(&rows));

    let mut result = ExperimentResult::new("fig1b");
    for r in &rows {
        result.push_row(&[
            ("fpu", Json::from(r.name.as_str())),
            ("area", Json::from(r.area)),
            ("relative", Json::from(r.relative)),
            ("reduction", Json::from(r.reduction)),
        ]);
    }

    // The paper's quantified claims.
    let a = |m: FpFormat, acc: FpFormat| model.area(&FpuConfig::new(m, acc));
    let fp16_acc = FpFormat::new(6, 9);
    let gain_16 = a(FpFormat::FP8_152, FpFormat::FP32) / a(FpFormat::FP8_152, fp16_acc);
    let gain_12 = a(FpFormat::FP8_152, FpFormat::FP32) / a(FpFormat::FP8_152, FpFormat::new(6, 5));
    println!("\nFP8 multiplier, 32b→16b accumulator: {gain_16:.2}x area reduction");
    println!("FP8 multiplier, 32b→12b accumulator: {gain_12:.2}x area reduction");
    println!("paper claims an extra 1.5–2.2x from reduced accumulation: {}",
        if (1.5..=2.2).contains(&gain_16) { "REPRODUCED" } else { "NOT reproduced" });
    result.note(format!("fp8 acc 32b->16b gain {gain_16:.2}x; ->12b gain {gain_12:.2}x"));

    ResultSink::new("results").unwrap().write(&result).unwrap();
    println!("wrote results/fig1b.json");
}
