//! Figure 3 regeneration: weight-gradient variance as a function of layer
//! index for the ResNet-18 topology — baseline (ideal accumulation)
//! versus reduced-precision GRAD accumulation — showing the abnormal
//! variance drop in the *early* layers (longest GRAD accumulations) and
//! the break point at the residual-block boundary where the accumulation
//! length drops 4×.
//!
//! The GRAD GEMM of each layer is simulated directly: ensembles of
//! length-`n_grad` accumulations of iid product terms at the layer's
//! gradient scale, through the bit-accurate simulator (this is exactly
//! what the GRAD inner loop computes per weight).

use abws::coordinator::experiment::{ExperimentResult, ResultSink};
use abws::coordinator::sweep::run_sweep;
use abws::mc::{empirical_vrr, McConfig};
use abws::nets::lengths::accum_lengths;
use abws::nets::resnet::resnet18_imagenet;
use abws::util::json::Json;
use abws::vrr::theorem::vrr;

fn main() {
    let net = resnet18_imagenet();
    // Well below the Conv0/ResBlock1 requirement (15/13), adequate for the
    // later blocks — the configuration that makes the Fig. 3 dent visible.
    let m_acc = 10;
    println!(
        "Fig 3: weight-gradient variance by layer, ResNet-18 topology, \
         GRAD accumulated at m_acc={m_acc} (prediction: 15 needed at layer 0)"
    );
    println!(
        "{:>5} {:<12} {:>9} {:>14} {:>14} {:>8} {:>8}",
        "layer", "group", "n_grad", "var(ideal)", "var(reduced)", "ratio", "theory"
    );

    let layers: Vec<(usize, String, usize)> = net
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| (i, l.group.clone(), accum_lengths(&net, l).grad))
        .collect();

    let rows = run_sweep(layers, 8, |(idx, group, n_grad)| {
        // σ_p of the gradient products: constant across layers in the He
        // picture; the *ideal* variance then scales with n_grad, and the
        // reduced-precision one shows the VRR dent.
        let mut cfg = McConfig::new(*n_grad, m_acc)
            .with_trials(48)
            .with_seed(9 + *idx as u64);
        // Per-trial RNG streams make the result bit-identical at any
        // thread count; 2 engine participants per sweep slot just keeps
        // the 8-way outer sweep from oversubscribing the pool.
        cfg.threads = 2;
        let r = empirical_vrr(&cfg).expect("48 trials, n_grad >= 1");
        (*idx, group.clone(), *n_grad, r)
    });

    let mut result = ExperimentResult::new("fig3");
    let mut first_block_ratio: f64 = 1.0;
    let mut late_ratio: f64 = 1.0;
    for (idx, group, n_grad, r) in &rows {
        let theory = vrr(m_acc, 5, *n_grad);
        println!(
            "{idx:>5} {group:<12} {n_grad:>9} {:>14.1} {:>14.1} {:>8.4} {:>8.4}",
            r.var_ideal, r.var_swamping, r.vrr, theory
        );
        if *idx <= 2 {
            first_block_ratio = first_block_ratio.min(r.vrr);
        }
        if *idx >= 13 {
            late_ratio = late_ratio.min(r.vrr);
        }
        result.push_row(&[
            ("layer", Json::from(*idx)),
            ("group", Json::from(group.as_str())),
            ("n_grad", Json::from(*n_grad)),
            ("var_ideal", Json::from(r.var_ideal)),
            ("var_reduced", Json::from(r.var_swamping)),
            ("vrr_measured", Json::from(r.vrr)),
            ("vrr_theory", Json::from(theory)),
        ]);
    }

    println!(
        "\nabnormality: early-layer variance retention {first_block_ratio:.3} vs \
         late-layer {late_ratio:.3} — the paper's Fig. 3 dent at the long-GRAD layers{}",
        if first_block_ratio < late_ratio - 0.05 {
            " (REPRODUCED)"
        } else {
            " (NOT reproduced)"
        }
    );
    result.note(format!(
        "early retention {first_block_ratio:.3}, late {late_ratio:.3}"
    ));

    ResultSink::new("results").unwrap().write(&result).unwrap();
    println!("wrote results/fig3.json");
}
