//! Figure 5 regeneration:
//! (a) normalized variance lost v(n) vs accumulation length, no chunking,
//!     m_acc ∈ {8..14};
//! (b) same with chunk-64 accumulation, m_acc ∈ {6..9};
//! (c) VRR vs chunk size for several accumulation setups (flat maxima),
//!     with the no-chunking VRR as the dashed reference.
//!
//! v(n) is reported in log space (log v = n(1-VRR); the cut-off is
//! ln 50 ≈ 3.91) because v itself overflows past the knee.

use abws::coordinator::experiment::{ExperimentResult, ResultSink};
use abws::coordinator::sweep::{default_threads, run_sweep};
use abws::mc::{sweep_vrr, AccumSetup, Ensemble};
use abws::util::bench;
use abws::util::json::Json;
use abws::vrr::chunking::vrr_chunked_total;
use abws::vrr::theorem::vrr;
use abws::vrr::variance_lost::{log_variance_lost, CUTOFF_LN};

fn lengths() -> Vec<usize> {
    // 2^6 .. 2^22, two points per octave.
    let mut ns = Vec::new();
    let mut n = 64usize;
    while n <= (1 << 22) {
        ns.push(n);
        ns.push(n + n / 2);
        n *= 2;
    }
    ns
}

fn knee(points: &[(usize, f64)]) -> Option<usize> {
    points.iter().find(|(_, lv)| *lv >= CUTOFF_LN).map(|(n, _)| *n)
}

fn main() {
    let mut result = ExperimentResult::new("fig5");
    let ns = lengths();

    // ---- (a) no chunking --------------------------------------------------
    println!("Fig 5(a): log v(n), normal accumulation (cut-off ln50 = {CUTOFF_LN:.2})");
    print!("{:>9}", "n");
    let maccs_a = [8u32, 9, 10, 11, 12, 13, 14];
    for m in maccs_a {
        print!(" {:>9}", format!("m={m}"));
    }
    println!();
    let mut curves_a = Vec::new();
    for &m in &maccs_a {
        let pts: Vec<(usize, f64)> = run_sweep(ns.clone(), 8, |&n| {
            (n, log_variance_lost(vrr(m, 5, n), n))
        });
        curves_a.push(pts);
    }
    for (i, &n) in ns.iter().enumerate() {
        print!("{n:>9}");
        for c in &curves_a {
            let lv = c[i].1;
            print!(" {:>9}", if lv > 9999.0 { ">1e4".into() } else { format!("{lv:.2}") });
        }
        println!();
    }
    for (m, c) in maccs_a.iter().zip(&curves_a) {
        let k = knee(c);
        println!("  m_acc={m}: max suitable n ≈ {:?}", k.map(|x| x / 2));
        result.push_row(&[
            ("panel", Json::from("a")),
            ("m_acc", Json::from(*m)),
            ("knee_n", Json::from(k.unwrap_or(0))),
        ]);
    }

    // ---- (b) chunk-64 ------------------------------------------------------
    println!("\nFig 5(b): log v(n), chunk-64 accumulation");
    let maccs_b = [6u32, 7, 8, 9];
    print!("{:>9}", "n");
    for m in maccs_b {
        print!(" {:>9}", format!("m={m}"));
    }
    println!();
    let mut curves_b = Vec::new();
    for &m in &maccs_b {
        let pts: Vec<(usize, f64)> = run_sweep(ns.clone(), 8, |&n| {
            (n, log_variance_lost(vrr_chunked_total(m, 5, n, 64), n))
        });
        curves_b.push(pts);
    }
    for (i, &n) in ns.iter().enumerate() {
        print!("{n:>9}");
        for c in &curves_b {
            let lv = c[i].1;
            print!(" {:>9}", if lv > 9999.0 { ">1e4".into() } else { format!("{lv:.2}") });
        }
        println!();
    }
    for (m, c) in maccs_b.iter().zip(&curves_b) {
        let k = knee(c);
        println!("  m_acc={m} (chunked): knee ≈ {k:?}");
        result.push_row(&[
            ("panel", Json::from("b")),
            ("m_acc", Json::from(*m)),
            ("knee_n", Json::from(k.unwrap_or(0))),
        ]);
    }

    // Cross-panel check (the chunking benefit): for the same m_acc, the
    // chunked knee sits at larger n.
    for &m in &[8u32, 9] {
        let ka = knee(&curves_a[maccs_a.iter().position(|&x| x == m).unwrap()]);
        let kb = knee(&curves_b[maccs_b.iter().position(|&x| x == m).unwrap()]);
        if let (Some(ka), Some(kb)) = (ka, kb) {
            println!("  m_acc={m}: knee moves {ka} → {kb} with chunking ({}x)", kb / ka.max(1));
        }
    }

    // ---- (c) VRR vs chunk size ---------------------------------------------
    println!("\nFig 5(c): VRR vs chunk size (dashed = no chunking)");
    let setups = [(1usize << 16, 8u32), (1 << 18, 9), (1 << 20, 10)];
    for (n, m) in setups {
        let mut chunks = Vec::new();
        let mut c = 2usize;
        while c <= n / 2 {
            chunks.push(c);
            c *= 2;
        }
        let vals = run_sweep(chunks.clone(), 8, |&c| vrr_chunked_total(m, 5, n, c));
        let plain = vrr(m, 5, n);
        println!("  n=2^{} m_acc={m}: plain VRR {plain:.4}", n.trailing_zeros());
        for (c, v) in chunks.iter().zip(&vals) {
            println!("    chunk {c:>7}: VRR {v:.5}");
        }
        // Flat maximum: best VRR region spans ≥ 4 octaves within 1%.
        let best = vals.iter().cloned().fold(0.0, f64::max);
        let flat = vals.iter().filter(|&&v| v > best - 0.01).count();
        println!("    flat-top width: {flat} octaves (≥4 expected)");
        result.push_row(&[
            ("panel", Json::from("c")),
            ("n", Json::from(n)),
            ("m_acc", Json::from(m)),
            ("plain_vrr", Json::from(plain)),
            ("best_vrr", Json::from(best)),
            ("flat_octaves", Json::from(flat)),
        ]);
    }

    // ---- (c) empirical overlay --------------------------------------------
    // Measure the first panel-(c) setup with the bit-accurate simulator:
    // every chunk size plus the unchunked dashed line in ONE engine
    // sweep, all scored against the same drawn ensemble.
    let (n, m) = setups[0];
    let mut chunks = Vec::new();
    let mut c = 2usize;
    while c <= n / 2 {
        chunks.push(c);
        c *= 4; // coarser than the theory curve: this one runs the simulator
    }
    let mut grid: Vec<AccumSetup> =
        chunks.iter().map(|&c| AccumSetup::new(m).with_chunk(c)).collect();
    grid.push(AccumSetup::new(m));
    let ens = Ensemble {
        n,
        m_p: 5,
        e_acc: 6,
        sigma_p: 1.0,
        trials: 24,
        seed: 0x5eed,
        threads: default_threads(),
    };
    let measured = sweep_vrr(&ens, &grid).expect("24 trials, non-empty grid");
    println!(
        "\nFig 5(c) empirical overlay: n=2^{} m_acc={m}, 24-trial Monte-Carlo \
         (one engine sweep, shared ensemble)",
        n.trailing_zeros()
    );
    for (c, r) in chunks.iter().zip(&measured) {
        println!(
            "    chunk {c:>7}: theory {:.5}  measured {:.5}",
            vrr_chunked_total(m, 5, n, *c),
            r.vrr
        );
        result.push_row(&[
            ("panel", Json::from("c_empirical")),
            ("n", Json::from(n)),
            ("m_acc", Json::from(m)),
            ("chunk", Json::from(*c)),
            ("vrr_theory", Json::from(vrr_chunked_total(m, 5, n, *c))),
            ("vrr_measured", Json::from(r.vrr)),
        ]);
    }
    let plain_measured = measured.last().expect("unchunked baseline");
    println!(
        "    {:>12}: theory {:.5}  measured {:.5}",
        "no chunking",
        vrr(m, 5, n),
        plain_measured.vrr
    );

    // Timing of a full panel-(a) sweep.
    bench::header();
    bench::quick("fig5a_single_curve_m10", || {
        for &n in &[1usize << 12, 1 << 16, 1 << 20] {
            std::hint::black_box(vrr(10, 5, n));
        }
    });

    ResultSink::new("results").unwrap().write(&result).unwrap();
    println!("wrote results/fig5.json");
}
