//! Figure 6 regeneration (scaled down): convergence at the predicted
//! accumulation precision and under precision perturbation (PP = 0, −1,
//! −2), for normal and chunk-64 accumulation; panel (d) is the final
//! accuracy degradation versus PP.
//!
//! Every arm is one [`abws::api::TrainRequest`] — the same typed query
//! `abws serve` answers — so the bench assembles no `PrecisionPlan` or
//! `AccumSpec` by hand and all six arms share the memoized solver.
//!
//! Paper claims to reproduce in shape:
//!  * PP = 0 converges within the baseline's noise band (±0.5% for the
//!    paper's nets; wider here because the task is small);
//!  * PP < 0 degrades, monotonically in the perturbation;
//!  * chunked runs are *more* sensitive per bit (their assignments are
//!    already lower).

use abws::api::train::PlanWidths;
use abws::api::{PlanSpec, PrecisionPolicy, TrainRequest};
use abws::coordinator::experiment::{ExperimentResult, ResultSink};
use abws::coordinator::sweep::run_sweep;
use abws::util::json::Json;

fn main() {
    // The shared task: dim 1024 (FWD length), 16 classes (BWD length),
    // batch 24 (GRAD length); noise projection ≈ 0.25·margin so the
    // baseline lands in the low-90s.
    let base = TrainRequest {
        policy: PrecisionPolicy::paper(),
        plan: PlanSpec::Baseline,
        dim: 1024,
        classes: 16,
        hidden: 48,
        steps: 150,
        batch: 24,
        seed: 3,
        data_seed: 13,
        n_train: 768,
        n_test: 512,
        noise: 8.0,
    };

    // One deterministic dataset, shared by the baseline and all six
    // sweep arms (they differ only in policy/plan).
    let (train, test) = abws::data::synth::generate(&base.dataset_spec());

    // Baseline arm.
    let baseline = base
        .resolve()
        .expect("baseline resolves")
        .run_on(&train, &test);
    let base_acc = baseline.test_acc;
    let base_loss = baseline.metrics.tail_loss(15).unwrap();
    println!("baseline: final loss {base_loss:.4}, test acc {base_acc:.3}");

    let mut grid = Vec::new();
    for chunked in [false, true] {
        for pp in [0i32, -1, -2] {
            grid.push((chunked, pp));
        }
    }

    let rows: Vec<(bool, i32, PlanWidths, abws::api::TrainReport)> =
        run_sweep(grid, 6, |&(chunked, pp)| {
            let req = TrainRequest {
                policy: PrecisionPolicy::paper().with_chunk(chunked.then_some(64)),
                plan: PlanSpec::Predicted { pp },
                ..base.clone()
            };
            let resolved = req.resolve().expect("predicted plan resolves");
            let widths = resolved.widths.expect("predicted plan has widths");
            (chunked, pp, widths, resolved.run_on(&train, &test))
        });

    let mut result = ExperimentResult::new("fig6");
    println!(
        "\n{:>8} {:>4} {:>12} {:>11} {:>9} {:>10} {:>9}",
        "mode", "PP", "m_acc(f/b/g)", "final loss", "test acc", "degrade", "diverged"
    );
    let mut degradations = std::collections::BTreeMap::new();
    for (chunked, pp, w, rep) in &rows {
        let label = if *chunked { "chunk-64" } else { "normal" };
        let degrade = base_acc - rep.test_acc;
        println!(
            "{label:>8} {pp:>4} {:>12} {:>11.4} {:>9.3} {:>10.3} {:>9}",
            format!("{}/{}/{}", w.fwd, w.bwd, w.grad),
            rep.metrics.tail_loss(15).unwrap_or(f64::NAN),
            rep.test_acc,
            degrade,
            rep.metrics.diverged
        );
        degradations.insert((*chunked, *pp), degrade);
        result.push_row(&[
            ("mode", Json::from(label)),
            ("pp", Json::from(*pp as i64)),
            ("m_fwd", Json::from(w.fwd)),
            ("m_bwd", Json::from(w.bwd)),
            ("m_grad", Json::from(w.grad)),
            (
                "final_loss",
                Json::from(rep.metrics.tail_loss(15).unwrap_or(f64::NAN)),
            ),
            ("test_acc", Json::from(rep.test_acc)),
            ("degradation", Json::from(degrade)),
            ("diverged", Json::from(rep.metrics.diverged)),
            (
                "loss_curve",
                rep.metrics.to_json().get("loss").unwrap().clone(),
            ),
        ]);
    }

    // Fig 6(d): degradation vs PP, shape checks. Degradation is measured
    // both in accuracy and in converged loss (the loss is the sensitive
    // instrument at this scale).
    println!("\nFig 6(d): degradation vs PP");
    let mut shape_ok = true;
    for chunked in [false, true] {
        let d0 = degradations[&(chunked, 0)];
        let d2 = degradations[&(chunked, -2)];
        let label = if chunked { "chunk-64" } else { "normal" };
        let tail = |pp: i32, missing: f64| -> f64 {
            rows.iter()
                .find(|r| r.0 == chunked && r.1 == pp)
                .map(|r| r.3.metrics.tail_loss(15).unwrap_or(missing))
                .unwrap()
        };
        let loss0 = tail(0, f64::NAN);
        let loss2 = tail(-2, f64::INFINITY);
        println!(
            "  {label}: acc-degrade PP=0 → {d0:.3}, PP=-2 → {d2:.3}; \
             loss PP=0 → {loss0:.4}, PP=-2 → {loss2:.4} (baseline {base_loss:.4})"
        );
        if d0 > 0.08 || loss0 > 2.0 * base_loss {
            shape_ok = false; // PP=0 must track the baseline
        }
        if d2 < d0 - 0.02 || loss2 < loss0 {
            shape_ok = false; // degradation must grow with perturbation
        }
    }
    println!(
        "paper shape (PP=0 ≈ baseline, PP<0 degrades): {}",
        if shape_ok { "REPRODUCED" } else { "NOT reproduced" }
    );
    result.note(format!("baseline acc {base_acc:.3}; shape_ok={shape_ok}"));

    ResultSink::new("results").unwrap().write(&result).unwrap();
    println!("wrote results/fig6.json");
}
