//! Figure 6 regeneration (scaled down): convergence at the predicted
//! accumulation precision and under precision perturbation (PP = 0, −1,
//! −2), for normal and chunk-64 accumulation; panel (d) is the final
//! accuracy degradation versus PP.
//!
//! Paper claims to reproduce in shape:
//!  * PP = 0 converges within the baseline's noise band (±0.5% for the
//!    paper's nets; wider here because the task is small);
//!  * PP < 0 degrades, monotonically in the perturbation;
//!  * chunked runs are *more* sensitive per bit (their assignments are
//!    already lower).

use abws::coordinator::experiment::{ExperimentResult, ResultSink};
use abws::coordinator::sweep::run_sweep;
use abws::data::synth::{generate, SynthSpec};
use abws::trainer::native::{NativeTrainer, PrecisionPlan, TrainConfig};
use abws::util::json::Json;
use abws::vrr::solver::{min_m_acc, perturbed, AccumSpec};

fn main() {
    let dim = 1024;
    let classes = 16;
    let spec = SynthSpec {
        n_train: 768,
        n_test: 512,
        dim,
        classes,
        noise: 8.0, // noise projection ≈ 0.25·margin — baseline lands in the low-90s
        seed: 13,
    };
    let (train, test) = generate(&spec);
    let cfg = TrainConfig {
        hidden: 48,
        steps: 150,
        batch: 24,
        seed: 3,
        log_every: 1,
        ..Default::default()
    };

    // Baseline arm.
    let mut tb = NativeTrainer::new(dim, classes, PrecisionPlan::baseline(), cfg);
    let mb = tb.train(&train);
    let base_acc = tb.evaluate(&test);
    println!(
        "baseline: final loss {:.4}, test acc {:.3}",
        mb.tail_loss(15).unwrap(),
        base_acc
    );

    // Predicted per-GEMM precisions for this model's accumulations.
    let predict = |chunk: Option<usize>| -> (u32, u32, u32) {
        let f = min_m_acc(&AccumSpec {
            n: dim,
            m_p: 5,
            nzr: 1.0,
            chunk,
        });
        let b = min_m_acc(&AccumSpec {
            n: classes,
            m_p: 5,
            nzr: 0.5,
            chunk,
        });
        let g = min_m_acc(&AccumSpec {
            n: cfg.batch,
            m_p: 5,
            nzr: 0.5,
            chunk,
        });
        (f, b, g)
    };

    let mut grid = Vec::new();
    for chunked in [false, true] {
        for pp in [0i32, -1, -2] {
            grid.push((chunked, pp));
        }
    }

    let rows = run_sweep(grid, 6, |&(chunked, pp)| {
        let chunk = if chunked { Some(64) } else { None };
        let (f, b, g) = predict(chunk);
        let plan = PrecisionPlan::per_gemm(
            perturbed(f, pp),
            perturbed(b, pp),
            perturbed(g, pp),
            chunk,
        );
        let mut t = NativeTrainer::new(dim, classes, plan, cfg);
        let m = t.train(&train);
        let acc = t.evaluate(&test);
        (chunked, pp, f, b, g, m, acc)
    });

    let mut result = ExperimentResult::new("fig6");
    println!(
        "\n{:>8} {:>4} {:>12} {:>11} {:>9} {:>10} {:>9}",
        "mode", "PP", "m_acc(f/b/g)", "final loss", "test acc", "degrade", "diverged"
    );
    let mut degradations = std::collections::BTreeMap::new();
    for (chunked, pp, f, b, g, m, acc) in &rows {
        let label = if *chunked { "chunk-64" } else { "normal" };
        let degrade = base_acc - acc;
        println!(
            "{label:>8} {pp:>4} {:>12} {:>11.4} {:>9.3} {:>10.3} {:>9}",
            format!(
                "{}/{}/{}",
                perturbed(*f, *pp),
                perturbed(*b, *pp),
                perturbed(*g, *pp)
            ),
            m.tail_loss(15).unwrap_or(f64::NAN),
            acc,
            degrade,
            m.diverged
        );
        degradations.insert((*chunked, *pp), degrade);
        result.push_row(&[
            ("mode", Json::from(label)),
            ("pp", Json::from(*pp as i64)),
            ("m_fwd", Json::from(perturbed(*f, *pp))),
            ("m_bwd", Json::from(perturbed(*b, *pp))),
            ("m_grad", Json::from(perturbed(*g, *pp))),
            ("final_loss", Json::from(m.tail_loss(15).unwrap_or(f64::NAN))),
            ("test_acc", Json::from(*acc)),
            ("degradation", Json::from(degrade)),
            ("diverged", Json::from(m.diverged)),
            ("loss_curve", m.to_json().get("loss").unwrap().clone()),
        ]);
    }

    // Fig 6(d): degradation vs PP, shape checks. Degradation is measured
    // both in accuracy and in converged loss (the loss is the sensitive
    // instrument at this scale).
    println!("\nFig 6(d): degradation vs PP");
    let base_loss = mb.tail_loss(15).unwrap();
    let mut shape_ok = true;
    for chunked in [false, true] {
        let d0 = degradations[&(chunked, 0)];
        let d2 = degradations[&(chunked, -2)];
        let label = if chunked { "chunk-64" } else { "normal" };
        let loss0 = rows
            .iter()
            .find(|r| r.0 == chunked && r.1 == 0)
            .map(|r| r.5.tail_loss(15).unwrap_or(f64::NAN))
            .unwrap();
        let loss2 = rows
            .iter()
            .find(|r| r.0 == chunked && r.1 == -2)
            .map(|r| r.5.tail_loss(15).unwrap_or(f64::INFINITY))
            .unwrap();
        println!(
            "  {label}: acc-degrade PP=0 → {d0:.3}, PP=-2 → {d2:.3}; \
             loss PP=0 → {loss0:.4}, PP=-2 → {loss2:.4} (baseline {base_loss:.4})"
        );
        if d0 > 0.08 || loss0 > 2.0 * base_loss {
            shape_ok = false; // PP=0 must track the baseline
        }
        if d2 < d0 - 0.02 || loss2 < loss0 {
            shape_ok = false; // degradation must grow with perturbation
        }
    }
    println!(
        "paper shape (PP=0 ≈ baseline, PP<0 degrades): {}",
        if shape_ok { "REPRODUCED" } else { "NOT reproduced" }
    );
    result.note(format!("baseline acc {base_acc:.3}; shape_ok={shape_ok}"));

    ResultSink::new("results").unwrap().write(&result).unwrap();
    println!("wrote results/fig6.json");
}
