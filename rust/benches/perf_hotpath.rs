//! Hot-path micro-benchmarks for the §Perf optimization loop:
//! * `vrr` formula evaluation (the solver's inner call — O(n) erfc loop);
//! * the solver (binary search over `vrr`);
//! * the api solve cache: a repeated Table-1 sweep, uncached vs memoized;
//! * softfloat quantize + sequential/chunked accumulation;
//! * reduced-precision GEMM (the native trainer's inner loop);
//! * the parallel GEMM kernel: a trainer-shaped product at 1/2/4
//!   threads, reporting MACs/s and an FNV-1a hash of the output bits —
//!   the run aborts if any thread count's hash differs from 1-thread
//!   (the bit-identity contract, enforced in CI);
//! * a full Monte-Carlo VRR point;
//! * the sweep-vectorized Monte-Carlo engine: a 10-config
//!   `(m_acc, chunk, rounding)` grid at 1/2/4 pool threads, reporting
//!   terms/s, an FNV-1a hash of every result's bits (the run aborts if
//!   any thread count diverges from the `empirical_vrr_ref` oracle), and
//!   the speedup over looping single-config `empirical_vrr` calls;
//! * telemetry overhead: the memoized sweep with recording off vs on;
//! * tracing overhead: the GEMM kernel with span instrumentation
//!   compiled in, measured disabled twice (the repeat delta bounds the
//!   noise floor — the disabled branch is one relaxed load) and enabled
//!   once; the acceptance criterion is <= 2% on the disabled path;
//! * serve throughput: a 200-line advisor batch through the pooled
//!   pipeline at 1 / 2 / 4 workers.
//!
//! Run before/after each optimization; EXPERIMENTS.md §Perf records the
//! iteration log. Besides the human-readable table, the run writes a
//! machine-readable `BENCH_perf.json` at the repo root: every
//! measurement, a per-phase telemetry snapshot diff (counters and
//! latency histograms accumulated by that phase), and the measured
//! telemetry on/off overhead — so the perf trajectory is tracked across
//! PRs.
//!
//! `--only <phase>` runs a single phase (solver, cache, softfloat, gemm,
//! gemm_kernel, mc, mc_engine, trace, serve) — CI uses this to smoke the
//! GEMM and MC-engine kernels in release mode without paying for the
//! full suite.

use std::time::Duration;

use abws::api::cache::SolveCache;
use abws::api::{serve_with, ServeOptions};
use abws::mc::{
    empirical_vrr, empirical_vrr_ref, sweep_vrr, AccumSetup, Ensemble, McConfig, McResult,
};
use abws::nets::alexnet::alexnet_imagenet;
use abws::nets::nzr::NzrModel;
use abws::nets::predict::{predict_network, predict_network_with};
use abws::nets::resnet::{resnet18_imagenet, resnet32_cifar10};
use abws::softfloat::accumulate::{chunked_sum, sequential_sum};
use abws::softfloat::format::FpFormat;
use abws::softfloat::gemm::{rp_gemm, rp_gemm_ex, rp_gemm_mxu, GemmConfig, GemmCtx, Layout};
use abws::softfloat::quant::{quantize, Rounding};
use abws::softfloat::tensor::Tensor;
use abws::telemetry;
use abws::util::bench::{bench, header, Measurement};
use abws::util::json::Json;
use abws::util::rng::Pcg64;
use abws::vrr::solver::{min_m_acc, AccumSpec};
use abws::vrr::theorem::vrr;

fn measurement_json(m: &Measurement) -> Json {
    let mut j = Json::obj();
    j.set("name", m.name.as_str());
    j.set("iters", m.iters as i64);
    j.set("median_ns", m.median.as_nanos() as u64);
    j.set("mean_ns", m.mean.as_nanos() as u64);
    j.set("stddev_ns", m.stddev.as_nanos() as u64);
    j.set("min_ns", m.min.as_nanos() as u64);
    j
}

/// FNV-1a over the little-endian bit patterns of the output — the hash
/// the CI smoke compares across thread counts (bit-identity contract).
fn fnv1a(data: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for x in data {
        for byte in x.to_bits().to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// FNV-1a over the f64 bit patterns of every Monte-Carlo result's
/// `(var_swamping, var_ideal, vrr)` triple, in grid order — the hash the
/// CI smoke compares between the engine sweep and the
/// `empirical_vrr_ref` oracle at every thread count.
fn mc_result_hash(results: &[McResult]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for r in results {
        for v in [r.var_swamping, r.var_ideal, r.vrr] {
            for byte in v.to_bits().to_le_bytes() {
                h ^= byte as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        }
    }
    h
}

/// Tracks per-phase telemetry deltas: every `close()` diffs the global
/// snapshot against the previous phase boundary.
struct Phases {
    last: telemetry::TelemetrySnapshot,
    out: Json,
}

impl Phases {
    fn start() -> Phases {
        Phases {
            last: telemetry::snapshot(),
            out: Json::obj(),
        }
    }

    fn close(&mut self, name: &str) {
        let now = telemetry::snapshot();
        self.out.set(name, now.diff(&self.last).to_json());
        self.last = now;
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let only = args
        .iter()
        .position(|a| a == "--only")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let run_phase = |name: &str| only.as_deref().is_none_or(|o| o == name);

    header();
    let budget = Duration::from_millis(700);
    let mut results: Vec<Measurement> = Vec::new();
    let mut phases = Phases::start();

    // --- VRR formula -------------------------------------------------------
    if run_phase("solver") {
        for log_n in [12u32, 16, 20] {
            let n = 1usize << log_n;
            results.push(bench(&format!("vrr(m=10, n=2^{log_n})"), budget, || {
                std::hint::black_box(vrr(10, 5, n))
            }));
        }
        results.push(bench("min_m_acc(n=2^20, plain)", budget, || {
            std::hint::black_box(min_m_acc(&AccumSpec::plain(1 << 20)))
        }));
        results.push(bench("min_m_acc(n=2^20, chunk64)", budget, || {
            std::hint::black_box(min_m_acc(&AccumSpec::plain(1 << 20).with_chunk(64)))
        }));
        phases.close("solver");
    }

    // --- memoized solving: the repeated-query sweep ------------------------
    // A Table-1 sweep over all three networks asks `min_m_acc` for every
    // (layer, GEMM, {normal, chunked}) — the workload `abws serve` repeats
    // per request. Uncached, each query re-runs the O(n) crossing sums;
    // through the api SolveCache every repeat is a hash lookup.
    let mut tel_overhead: Option<(Measurement, Measurement, f64)> = None;
    if run_phase("cache") {
        let nets = [
            (resnet32_cifar10(), NzrModel::resnet_default()),
            (resnet18_imagenet(), NzrModel::resnet_default()),
            (alexnet_imagenet(), NzrModel::alexnet_default()),
        ];
        let uncached = bench("table1 sweep x3 nets (uncached)", budget, || {
            for (net, nzr) in &nets {
                std::hint::black_box(predict_network(net, nzr, 5, 64));
            }
        });
        let cache = SolveCache::new();
        let memoized = bench("table1 sweep x3 nets (memoized)", budget, || {
            for (net, nzr) in &nets {
                std::hint::black_box(predict_network_with(net, nzr, 5, 64, |s| {
                    cache.min_m_acc(s)
                }));
            }
        });
        let stats = cache.stats();
        println!(
            "  -> memoization speedup on the repeated sweep: {:.0}x \
             ({} cached solves, {} hits)",
            uncached.median.as_secs_f64() / memoized.median.as_secs_f64().max(1e-12),
            stats.solve_entries,
            stats.hits,
        );
        results.push(uncached);
        results.push(memoized);

        // --- telemetry overhead: memoized sweep, recording off vs on --------
        // Acceptance criterion: the instrumented hot path (cache hits
        // through an instrumented SolveCache, solver counters on the rare
        // misses) must cost < 5% over the same path with telemetry disabled.
        let icache = SolveCache::instrumented();
        let sweep = |c: &SolveCache| {
            for (net, nzr) in &nets {
                std::hint::black_box(predict_network_with(net, nzr, 5, 64, |s| c.min_m_acc(s)));
            }
        };
        sweep(&icache); // warm the cache: both arms measure the hit path
        telemetry::set_enabled(false);
        let tel_off = bench("memoized sweep (telemetry off)", budget, || sweep(&icache));
        telemetry::set_enabled(true);
        let tel_on = bench("memoized sweep (telemetry on)", budget, || sweep(&icache));
        let overhead_pct = 100.0
            * (tel_on.median.as_secs_f64() - tel_off.median.as_secs_f64())
            / tel_off.median.as_secs_f64().max(1e-12);
        println!("  -> telemetry overhead on the memoized sweep: {overhead_pct:.2}%");
        results.push(tel_off.clone());
        results.push(tel_on.clone());
        tel_overhead = Some((tel_off, tel_on, overhead_pct));
        phases.close("cache");
    }

    // --- softfloat primitives ------------------------------------------------
    if run_phase("softfloat") {
        let mut rng = Pcg64::seeded(1);
        let terms: Vec<f64> = (0..65_536).map(|_| rng.normal()).collect();
        let fmt = FpFormat::accumulator(10);
        results.push(bench("quantize x 64k", budget, || {
            let mut acc = 0.0;
            for &t in &terms {
                acc += quantize(t, fmt, Rounding::NearestEven);
            }
            acc
        }));
        results.push(bench("sequential_sum 64k @ m=10", budget, || {
            sequential_sum(&terms, fmt, Rounding::NearestEven)
        }));
        results.push(bench("chunked_sum 64k @ m=10 c=64", budget, || {
            chunked_sum(&terms, 64, fmt, Rounding::NearestEven)
        }));
        phases.close("softfloat");
    }

    // --- reduced-precision GEMM ----------------------------------------------
    if run_phase("gemm") {
        let mut rng = Pcg64::seeded(2);
        let a = Tensor::randn(&[16, 1024], 1.0, &mut rng);
        let b = Tensor::randn(&[1024, 16], 1.0, &mut rng);
        let cfg = GemmConfig::paper(10, None);
        results.push(bench("rp_gemm 16x1024x16 seq", budget, || {
            std::hint::black_box(rp_gemm(&a, &b, &cfg))
        }));
        let cfg_c = GemmConfig::paper(10, Some(64));
        results.push(bench("rp_gemm 16x1024x16 chunk64", budget, || {
            std::hint::black_box(rp_gemm(&a, &b, &cfg_c))
        }));
        results.push(bench("rp_gemm_mxu 16x1024x16 c=64", budget, || {
            std::hint::black_box(rp_gemm_mxu(&a, &b, &cfg_c, 64))
        }));
        phases.close("gemm");
    }

    // --- parallel GEMM kernel: threads sweep + bit-identity hash --------------
    // A trainer-shaped product (batch-panel rows, long k) through the
    // pooled kernel at 1/2/4 threads. MACs/s per arm goes into the JSON;
    // the FNV-1a output hash MUST be identical across arms — any
    // divergence is a determinism bug, and the run aborts so CI fails.
    let mut gemm_kernel: Option<Json> = None;
    if run_phase("gemm_kernel") {
        let mut rng = Pcg64::seeded(21);
        let (m, k, n) = (32usize, 4096usize, 32usize);
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let kcfg = GemmConfig::paper(8, Some(64));
        let macs = (m * k * n) as f64;
        let mut out_json = Json::obj();
        let mut hashes: Vec<u64> = Vec::new();
        let mut rates: Vec<f64> = Vec::new();
        for threads in [1usize, 2, 4] {
            let ctx = GemmCtx {
                threads,
                ..GemmCtx::default()
            };
            let out = rp_gemm_ex(&a, &b, &kcfg, Layout::NN, &ctx).unwrap();
            let hash = fnv1a(&out.data);
            let meas = bench(
                &format!("rp_gemm_ex {m}x{k}x{n} chunk64, {threads} thr"),
                budget,
                || std::hint::black_box(rp_gemm_ex(&a, &b, &kcfg, Layout::NN, &ctx).unwrap()),
            );
            let rate = macs / meas.median.as_secs_f64().max(1e-12);
            println!(
                "  -> {threads} thread(s): {:.1}M MACs/s, output hash {hash:016x}",
                rate / 1e6
            );
            let mut arm = Json::obj();
            arm.set("median_ns", meas.median.as_nanos() as u64);
            arm.set("macs_per_sec", rate);
            arm.set("hash", format!("{hash:016x}"));
            out_json.set(&format!("threads_{threads}"), arm);
            hashes.push(hash);
            rates.push(rate);
            results.push(meas);
        }
        if hashes.iter().any(|&h| h != hashes[0]) {
            eprintln!(
                "FATAL: parallel GEMM output hash diverged from the 1-thread hash: {hashes:016x?}"
            );
            std::process::exit(1);
        }
        let speedup = rates[2] / rates[0].max(1e-12);
        println!("  -> 4-thread vs 1-thread speedup: {speedup:.2}x");
        out_json.set("speedup_4v1", speedup);
        gemm_kernel = Some(out_json);
        phases.close("gemm_kernel");
    }

    // --- Monte-Carlo point -----------------------------------------------------
    if run_phase("mc") {
        let mut mc = McConfig::new(16_384, 8).with_trials(32);
        mc.threads = 4;
        results.push(bench("empirical_vrr n=16k t=32", Duration::from_secs(2), || {
            std::hint::black_box(empirical_vrr(&mc).expect("mc bench config is valid"))
        }));
        phases.close("mc");
    }

    // --- sweep-vectorized Monte-Carlo engine: threads sweep + oracle hash ------
    // A Fig.5-shaped grid — four widths, plain and chunk-64, plus two
    // truncating configs — scored in one engine pass per arm. Every arm's
    // result hash MUST equal the single-config `empirical_vrr_ref` oracle
    // hash (bit-identity contract at any thread count); any divergence
    // aborts the run so CI fails. The looped arm runs the same grid as
    // ten one-config `empirical_vrr` calls — the sweep's advantage is one
    // draw-and-quantize ensemble pass instead of ten.
    let mut mc_engine: Option<Json> = None;
    if run_phase("mc_engine") {
        let (n, trials, seed) = (4096usize, 32usize, 0x5eedu64);
        let mut grid: Vec<AccumSetup> = Vec::new();
        for m in [5u32, 7, 9, 11] {
            grid.push(AccumSetup::new(m));
            grid.push(AccumSetup::new(m).with_chunk(64));
        }
        grid.push(AccumSetup::new(7).with_rounding(Rounding::TowardZero));
        grid.push(
            AccumSetup::new(7)
                .with_chunk(64)
                .with_rounding(Rounding::TowardZero),
        );
        let as_config = |s: &AccumSetup| {
            let mut cfg = McConfig::new(n, s.m_acc)
                .with_trials(trials)
                .with_seed(seed)
                .with_rounding(s.rounding);
            if let Some(c) = s.chunk {
                cfg = cfg.with_chunk(c);
            }
            cfg.threads = 4;
            cfg
        };

        let ref_results: Vec<McResult> =
            grid.iter().map(|s| empirical_vrr_ref(&as_config(s))).collect();
        let ref_hash = mc_result_hash(&ref_results);
        println!("  -> empirical_vrr_ref oracle hash {ref_hash:016x}");

        let terms_total = (trials * n) as f64;
        let mut out_json = Json::obj();
        out_json.set("grid_width", grid.len());
        out_json.set("ref_hash", format!("{ref_hash:016x}"));
        let mut engine4_median = f64::MAX;
        for threads in [1usize, 2, 4] {
            let ens = Ensemble {
                n,
                m_p: 5,
                e_acc: 6,
                sigma_p: 1.0,
                trials,
                seed,
                threads,
            };
            let got = sweep_vrr(&ens, &grid).expect("bench grid is valid");
            let hash = mc_result_hash(&got);
            if hash != ref_hash {
                eprintln!(
                    "FATAL: engine sweep hash {hash:016x} at {threads} thread(s) \
                     diverged from the empirical_vrr_ref oracle hash {ref_hash:016x}"
                );
                std::process::exit(1);
            }
            let meas = bench(
                &format!("mc engine sweep x{} n=4k t=32, {threads} thr", grid.len()),
                budget,
                || std::hint::black_box(sweep_vrr(&ens, &grid).expect("bench grid is valid")),
            );
            let rate = terms_total / meas.median.as_secs_f64().max(1e-12);
            println!(
                "  -> {threads} thread(s): {:.1}M terms/s, result hash {hash:016x}",
                rate / 1e6
            );
            if threads == 4 {
                engine4_median = meas.median.as_secs_f64();
            }
            let mut arm = Json::obj();
            arm.set("median_ns", meas.median.as_nanos() as u64);
            arm.set("terms_per_sec", rate);
            arm.set("hash", format!("{hash:016x}"));
            out_json.set(&format!("threads_{threads}"), arm);
            results.push(meas);
        }

        let looped = bench(
            &format!("mc looped empirical_vrr x{}, 4 thr", grid.len()),
            budget,
            || {
                for s in &grid {
                    std::hint::black_box(
                        empirical_vrr(&as_config(s)).expect("bench grid is valid"),
                    );
                }
            },
        );
        let speedup = looped.median.as_secs_f64() / engine4_median.max(1e-12);
        println!("  -> engine sweep vs looped single-config calls at 4 threads: {speedup:.2}x");
        out_json.set("looped_median_ns", looped.median.as_nanos() as u64);
        out_json.set("sweep_speedup_vs_looped", speedup);
        results.push(looped);
        mc_engine = Some(out_json);
        phases.close("mc_engine");
    }

    // --- tracing overhead: GEMM with spans compiled in, disabled vs on ---------
    // The span callsites ship in the product binary; the acceptance
    // criterion is that with tracing *disabled* (the default) they cost
    // <= 2% GEMM throughput. Two disabled runs bound the measurement
    // noise — the disabled branch is a single relaxed load — and the
    // enabled run prices actual span recording for reference.
    let mut trace_overhead: Option<Json> = None;
    if run_phase("trace") {
        let mut rng = Pcg64::seeded(23);
        let (m, k, n) = (16usize, 2048usize, 16usize);
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let tcfg = GemmConfig::paper(8, Some(64));
        let ctx = GemmCtx {
            threads: 1,
            ..GemmCtx::default()
        };
        let run = || std::hint::black_box(rp_gemm_ex(&a, &b, &tcfg, Layout::NN, &ctx).unwrap());
        telemetry::trace::set_enabled(false);
        let off_a = bench("rp_gemm_ex 16x2048x16, trace off (a)", budget, run);
        let off_b = bench("rp_gemm_ex 16x2048x16, trace off (b)", budget, run);
        telemetry::trace::set_enabled(true);
        let on = bench("rp_gemm_ex 16x2048x16, trace on", budget, run);
        telemetry::trace::set_enabled(false);
        telemetry::trace::clear();
        let macs = (m * k * n) as f64;
        let off_med = off_a.median.as_secs_f64().max(1e-12);
        let disabled_delta_pct =
            100.0 * (off_b.median.as_secs_f64() - off_med).abs() / off_med;
        let enabled_overhead_pct = 100.0 * (on.median.as_secs_f64() - off_med) / off_med;
        println!(
            "  -> trace disabled: {:.1}M MACs/s (repeat delta {disabled_delta_pct:.2}%), \
             enabled overhead {enabled_overhead_pct:.2}%",
            macs / off_med / 1e6
        );
        let mut tj = Json::obj();
        tj.set("off_median_ns", off_a.median.as_nanos() as u64);
        tj.set("off_repeat_median_ns", off_b.median.as_nanos() as u64);
        tj.set("on_median_ns", on.median.as_nanos() as u64);
        tj.set("disabled_macs_per_sec", macs / off_med);
        tj.set("disabled_delta_pct", disabled_delta_pct);
        tj.set("enabled_overhead_pct", enabled_overhead_pct);
        trace_overhead = Some(tj);
        results.push(off_a);
        results.push(off_b);
        results.push(on);
        phases.close("trace");
    }

    // --- serve pipeline throughput ---------------------------------------------
    // A 200-line advisor batch over the three builtin networks, answered
    // through the pooled `serve_with` pipeline. The first (unmeasured)
    // pass warms the process-global solve cache so every arm measures the
    // same memoized workload; the arms differ only in worker count.
    let mut serve_throughput: Option<Json> = None;
    if run_phase("serve") {
        let batch: String = (0..200)
            .map(|i| {
                let net = ["resnet32", "resnet18", "alexnet"][i % 3];
                format!("{{\"type\":\"advisor\",\"network\":\"{net}\",\"id\":{i}}}\n")
            })
            .collect();
        let serve_once = |workers: usize| {
            let opts = ServeOptions {
                workers,
                ..ServeOptions::default()
            };
            let mut sink = Vec::with_capacity(1 << 20);
            serve_with(batch.as_bytes(), &mut sink, &opts).expect("serve bench batch failed");
            sink.len()
        };
        serve_once(1); // warm the solve cache
        let mut arms = Json::obj();
        for workers in [1usize, 2, 4] {
            let m = bench(
                &format!("serve 200 advisors, {workers} worker(s)"),
                budget,
                || std::hint::black_box(serve_once(workers)),
            );
            let reqs_per_s = 200.0 / m.median.as_secs_f64().max(1e-12);
            println!("  -> {workers} worker(s): {reqs_per_s:.0} req/s");
            let mut arm = Json::obj();
            arm.set("median_ns", m.median.as_nanos() as u64);
            arm.set("requests_per_sec", reqs_per_s);
            arms.set(&format!("workers_{workers}"), arm);
            results.push(m);
        }
        serve_throughput = Some(arms);
        phases.close("serve");
    }

    // --- machine-readable output ----------------------------------------------
    let mut root = Json::obj();
    root.set(
        "benchmarks",
        Json::Arr(results.iter().map(measurement_json).collect()),
    );
    root.set("phases", phases.out);
    if let Some((tel_off, tel_on, overhead_pct)) = tel_overhead {
        let mut overhead = Json::obj();
        overhead.set("off_median_ns", tel_off.median.as_nanos() as u64);
        overhead.set("on_median_ns", tel_on.median.as_nanos() as u64);
        overhead.set("overhead_pct", overhead_pct);
        root.set("telemetry_overhead", overhead);
    }
    if let Some(t) = trace_overhead {
        root.set("trace_overhead", t);
    }
    if let Some(st) = serve_throughput {
        root.set("serve_throughput", st);
    }
    if let Some(gk) = gemm_kernel {
        root.set("gemm_kernel", gk);
    }
    if let Some(me) = mc_engine {
        root.set("mc_engine", me);
    }

    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_perf.json");
    match std::fs::write(path, format!("{root}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => {
            // The JSON artifact is the whole point of the run: a silent
            // skip would let CI report a perf pass with no record.
            eprintln!("FATAL: could not write {path}: {e}");
            std::process::exit(1);
        }
    }
}
