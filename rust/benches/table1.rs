//! Table 1 regeneration: predicted (normal, chunked) accumulation
//! mantissa widths per layer group and GEMM for the paper's three
//! benchmark networks, printed next to the paper's reported values, plus
//! a timing of the whole prediction pipeline.

use abws::api::{advise_builtin, PrecisionPolicy};
use abws::coordinator::experiment::{ExperimentResult, ResultSink};
use abws::util::bench;
use abws::util::json::Json;

/// Paper Table 1, transcribed: (net, gemm, group) -> (normal, chunked).
const PAPER: &[(&str, &str, &str, u32, u32)] = &[
    // CIFAR-10 ResNet 32
    ("resnet32", "FWD", "Conv 0", 6, 5),
    ("resnet32", "FWD", "ResBlock 1", 6, 5),
    ("resnet32", "FWD", "ResBlock 2", 7, 5),
    ("resnet32", "FWD", "ResBlock 3", 7, 5),
    ("resnet32", "BWD", "ResBlock 1", 6, 5),
    ("resnet32", "BWD", "ResBlock 2", 7, 5),
    ("resnet32", "BWD", "ResBlock 3", 8, 5),
    ("resnet32", "GRAD", "Conv 0", 11, 8),
    ("resnet32", "GRAD", "ResBlock 1", 11, 8),
    ("resnet32", "GRAD", "ResBlock 2", 10, 6),
    ("resnet32", "GRAD", "ResBlock 3", 9, 6),
    // ImageNet ResNet 18
    ("resnet18", "FWD", "Conv 0", 9, 6),
    ("resnet18", "FWD", "ResBlock 1", 7, 5),
    ("resnet18", "FWD", "ResBlock 2", 8, 5),
    ("resnet18", "FWD", "ResBlock 3", 8, 5),
    ("resnet18", "FWD", "ResBlock 4", 9, 6),
    ("resnet18", "BWD", "ResBlock 1", 8, 6),
    ("resnet18", "BWD", "ResBlock 2", 9, 6),
    ("resnet18", "BWD", "ResBlock 3", 9, 6),
    ("resnet18", "BWD", "ResBlock 4", 10, 6),
    ("resnet18", "GRAD", "Conv 0", 15, 10),
    ("resnet18", "GRAD", "ResBlock 1", 15, 9),
    ("resnet18", "GRAD", "ResBlock 2", 12, 8),
    ("resnet18", "GRAD", "ResBlock 3", 10, 6),
    ("resnet18", "GRAD", "ResBlock 4", 9, 5),
    // ImageNet AlexNet
    ("alexnet", "FWD", "Conv 1", 7, 5),
    ("alexnet", "FWD", "Conv 2", 9, 5),
    ("alexnet", "FWD", "Conv 3", 9, 5),
    ("alexnet", "FWD", "Conv 4", 8, 5),
    ("alexnet", "FWD", "Conv 5", 8, 5),
    ("alexnet", "FWD", "FC 1", 9, 6),
    ("alexnet", "FWD", "FC 2", 8, 5),
    ("alexnet", "BWD", "Conv 2", 8, 5),
    ("alexnet", "BWD", "Conv 3", 8, 5),
    ("alexnet", "BWD", "Conv 4", 10, 8),
    ("alexnet", "BWD", "Conv 5", 8, 5),
    ("alexnet", "BWD", "FC 1", 8, 5),
    ("alexnet", "BWD", "FC 2", 8, 5),
    ("alexnet", "GRAD", "Conv 1", 10, 7),
    ("alexnet", "GRAD", "Conv 2", 9, 6),
    ("alexnet", "GRAD", "Conv 3", 8, 6),
    ("alexnet", "GRAD", "Conv 4", 6, 5),
    ("alexnet", "GRAD", "Conv 5", 6, 5),
    ("alexnet", "GRAD", "FC 1", 6, 5),
    ("alexnet", "GRAD", "FC 2", 6, 5),
];

fn main() {
    // One policy describes the whole Table-1 setup; every network goes
    // through the api advisor (and therefore the memoized solver).
    let policy = PrecisionPolicy::paper().with_chunk(Some(64));
    let keys = ["resnet32", "resnet18", "alexnet"];

    let mut result = ExperimentResult::new("table1");
    let mut abs_err_normal = Vec::new();
    let mut abs_err_chunked = Vec::new();

    for key in keys {
        let report = advise_builtin(key, &policy)
            .expect("builtin network")
            .remove(0);
        println!("{}", report.render());
        for &(pkey, gemm, group, p_normal, p_chunked) in PAPER {
            if pkey != key {
                continue;
            }
            if let Some(p) = report.prediction.group_prediction(group, gemm) {
                let en = (p.normal as i64 - p_normal as i64).abs();
                let ec = (p.chunked as i64 - p_chunked as i64).abs();
                abs_err_normal.push(en as f64);
                abs_err_chunked.push(ec as f64);
                result.push_row(&[
                    ("net", Json::from(key)),
                    ("gemm", Json::from(gemm)),
                    ("group", Json::from(group)),
                    ("paper_normal", Json::from(p_normal)),
                    ("ours_normal", Json::from(p.normal)),
                    ("paper_chunked", Json::from(p_chunked)),
                    ("ours_chunked", Json::from(p.chunked)),
                ]);
            }
        }
    }

    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    let within1 = |v: &[f64]| {
        v.iter().filter(|&&e| e <= 1.0).count() as f64 / v.len().max(1) as f64
    };
    println!(
        "paper-vs-ours |err|: normal mean {:.2} bits ({:.0}% within ±1), \
         chunked mean {:.2} bits ({:.0}% within ±1)  [{} cells]",
        mean(&abs_err_normal),
        100.0 * within1(&abs_err_normal),
        mean(&abs_err_chunked),
        100.0 * within1(&abs_err_chunked),
        abs_err_normal.len(),
    );
    result.note(format!(
        "normal-column mean abs err {:.2} bits, chunked-column {:.2} bits",
        mean(&abs_err_normal),
        mean(&abs_err_chunked)
    ));

    // Ablation (DESIGN.md / solver.rs): the chunked-column suitability
    // criterion. Per-level v(n) (default) vs total-length v(n)
    // (`suitable_total`) on the longest GRAD accumulations.
    println!("\nAblation — chunked criterion (ResNet-18 GRAD lengths):");
    println!(
        "{:>10} {:>14} {:>16} {:>12}",
        "n", "normal", "chunk(per-level)", "chunk(total)"
    );
    use abws::vrr::solver::M_ACC_MAX;
    for n in [3_211_264usize, 802_816, 200_704, 50_176, 12_544] {
        let spec = policy.clone().with_chunk(None).accum_spec(n, 0.5);
        let chunked_spec = policy.accum_spec(n, 0.5); // chunk 64 from the policy
        let normal = abws::api::cache::min_m_acc(&spec);
        let chunked = abws::api::cache::min_m_acc(&chunked_spec);
        let total = (1..=M_ACC_MAX)
            .find(|&m| chunked_spec.suitable_total(m))
            .unwrap_or(M_ACC_MAX);
        println!("{n:>10} {normal:>14} {chunked:>16} {total:>12}");
        result.push_row(&[
            ("ablation", Json::from("chunk_criterion")),
            ("n", Json::from(n)),
            ("normal", Json::from(normal)),
            ("chunk_per_level", Json::from(chunked)),
            ("chunk_total", Json::from(total)),
        ]);
    }
    println!(
        "(the paper's Table-1 chunked savings of up to 6 bits match the \
         per-level reading; the total-length reading saves ≤2 bits)"
    );

    // Timing: the full three-network Table 1 (the "no brute-force
    // emulation needed" claim quantified). The api path hits the
    // process-wide solve cache warmed by the runs above — this is the
    // steady-state latency a `serve` batch sees; `cargo bench --bench
    // perf_hotpath` reports cold-vs-warm side by side.
    bench::header();
    bench::quick("predict_table1_all_networks (api, memoized)", || {
        for key in keys {
            std::hint::black_box(advise_builtin(key, &policy).expect("builtin network"));
        }
    });

    let sink = ResultSink::new("results").unwrap();
    sink.write(&result).unwrap();
    println!("wrote results/table1.json");
}
