//! The advisory workflow the paper's conclusion describes — "determining
//! the accumulation bit-width requirements … without computationally
//! prohibitive brute-force emulations" — as one typed request/response
//! pair: [`AdvisorRequest`] (a network plus a [`PrecisionPolicy`]) in,
//! [`AdvisorReport`] (per-layer and per-group minimum accumulator
//! mantissa widths, normal and chunked) out. Both sides round-trip
//! through [`crate::util::json`] for the [`crate::api::serve`] batch
//! front-end, and all solving goes through the memoized
//! [`crate::api::cache`].

use anyhow::{bail, Context, Result};

use super::cache;
use super::policy::{PrecisionPolicy, DEFAULT_ADVISOR_CHUNK, DEFAULT_RELU_NZR};
use crate::nets::alexnet::alexnet_imagenet;
use crate::nets::layer::{Layer, LayerKind, Network};
use crate::nets::lengths::{AccumLengths, Gemm};
use crate::nets::nzr::NzrModel;
use crate::nets::predict::{predict_network_with, LayerPrediction, NetworkPrediction, Prediction};
use crate::nets::resnet::{resnet18_imagenet, resnet32_cifar10};
use crate::util::json::Json;

/// The network a request analyzes: one of the paper's calibrated
/// benchmarks by name, or a custom topology shipped in the request.
#[derive(Clone, Debug)]
pub enum NetworkSpec {
    /// `"resnet32"`, `"resnet18"` or `"alexnet"` — resolved with its
    /// calibrated NZR model.
    Builtin(String),
    /// A caller-described topology; sparsity defaults to the ReLU model
    /// `(1.0, 0.5, 0.5)` unless the policy pins one.
    Custom(Network),
}

/// The builtin benchmark keys, in paper order — the single source of
/// truth consulted by both [`NetworkSpec::resolve`] and
/// [`builtin_keys`]; extend [`builtin_network`] alongside it.
pub const BUILTIN_NETWORKS: &[&str] = &["resnet32", "resnet18", "alexnet"];

/// Construct a builtin benchmark with its calibrated sparsity model.
fn builtin_network(name: &str) -> Option<(Network, NzrModel)> {
    Some(match name {
        "resnet32" => (resnet32_cifar10(), NzrModel::resnet_default()),
        "resnet18" => (resnet18_imagenet(), NzrModel::resnet_default()),
        "alexnet" => (alexnet_imagenet(), NzrModel::alexnet_default()),
        _ => return None,
    })
}

impl NetworkSpec {
    /// Resolve to a concrete topology plus its default sparsity model.
    pub fn resolve(&self) -> Result<(Network, NzrModel)> {
        match self {
            NetworkSpec::Builtin(name) => builtin_network(name).with_context(|| {
                format!(
                    "unknown network '{name}' ({})",
                    BUILTIN_NETWORKS.join("|")
                )
            }),
            NetworkSpec::Custom(net) => {
                if net.layers.is_empty() {
                    bail!("custom network has no layers");
                }
                let relu = NzrModel::uniform(
                    DEFAULT_RELU_NZR.fwd,
                    DEFAULT_RELU_NZR.bwd,
                    DEFAULT_RELU_NZR.grad,
                );
                Ok((net.clone(), relu))
            }
        }
    }
}

/// Expand a CLI-style network selector (`all` included) into builtin keys.
pub fn builtin_keys(name: &str) -> Result<Vec<&'static str>> {
    if name == "all" {
        return Ok(BUILTIN_NETWORKS.to_vec());
    }
    match BUILTIN_NETWORKS.iter().find(|k| **k == name) {
        Some(k) => Ok(vec![*k]),
        None => bail!(
            "unknown network '{name}' ({}|all)",
            BUILTIN_NETWORKS.join("|")
        ),
    }
}

/// One precision-advisory query.
#[derive(Clone, Debug)]
pub struct AdvisorRequest {
    pub network: NetworkSpec,
    pub policy: PrecisionPolicy,
    /// Which GEMMs to report on (empty is normalized to all three).
    pub gemms: Vec<Gemm>,
}

impl AdvisorRequest {
    pub fn builtin(name: &str, policy: PrecisionPolicy) -> AdvisorRequest {
        AdvisorRequest {
            network: NetworkSpec::Builtin(name.to_string()),
            policy,
            gemms: Gemm::ALL.to_vec(),
        }
    }

    pub fn custom(net: Network, policy: PrecisionPolicy) -> AdvisorRequest {
        AdvisorRequest {
            network: NetworkSpec::Custom(net),
            policy,
            gemms: Gemm::ALL.to_vec(),
        }
    }

    /// Telemetry label for this request's network: a builtin key,
    /// `"custom"`, or `"unknown"` — bounded cardinality even when fed
    /// arbitrary (invalid) names from `serve` traffic.
    fn telemetry_label(&self) -> &'static str {
        match &self.network {
            NetworkSpec::Custom(_) => "custom",
            NetworkSpec::Builtin(name) => BUILTIN_NETWORKS
                .iter()
                .find(|k| **k == name.as_str())
                .copied()
                .unwrap_or("unknown"),
        }
    }

    /// Run the analysis through the process-wide solve cache.
    pub fn run(&self) -> Result<AdvisorReport> {
        let _tspan = if crate::telemetry::trace::enabled() {
            crate::telemetry::trace::TraceSpan::enter("advisor.run")
                .attr("network", self.telemetry_label())
        } else {
            crate::telemetry::trace::TraceSpan::noop()
        };
        let _span = if crate::telemetry::enabled() {
            let label = self.telemetry_label();
            crate::telemetry::counter(&crate::telemetry::labeled(
                "abws_advisor_requests_total",
                &[("network", label)],
            ))
            .inc();
            crate::telemetry::Span::enter(crate::telemetry::histogram(
                &crate::telemetry::labeled("abws_advisor_latency_ns", &[("network", label)]),
            ))
        } else {
            crate::telemetry::Span::noop()
        };
        self.policy.validate()?;
        let (net, default_nzr) = self.network.resolve()?;
        let nzr = self.policy.nzr.clone().unwrap_or(default_nzr);
        let chunk = self.policy.chunk.unwrap_or(DEFAULT_ADVISOR_CHUNK);
        let gemms = if self.gemms.is_empty() {
            Gemm::ALL.to_vec()
        } else {
            self.gemms.clone()
        };
        let mut prediction =
            predict_network_with(&net, &nzr, self.policy.m_p, chunk, cache::min_m_acc);
        // Narrow the report to the requested GEMMs.
        if gemms.len() < Gemm::ALL.len() {
            let keep: Vec<&'static str> = gemms.iter().map(Gemm::name).collect();
            for lp in &mut prediction.layers {
                lp.per_gemm.retain(|k, _| keep.contains(k));
            }
            for (_, agg) in &mut prediction.groups {
                agg.retain(|k, _| keep.contains(k));
            }
        }
        Ok(AdvisorReport { gemms, prediction })
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("type", "advisor");
        j.set(
            "network",
            match &self.network {
                NetworkSpec::Builtin(name) => Json::from(name.as_str()),
                NetworkSpec::Custom(net) => network_to_json(net),
            },
        );
        j.set("policy", self.policy.to_json());
        j.set("gemms", gemms_to_json(&self.gemms));
        j
    }

    pub fn from_json(j: &Json) -> Result<AdvisorRequest> {
        let network = match j.get("network") {
            Some(Json::Str(s)) => NetworkSpec::Builtin(s.clone()),
            Some(obj @ Json::Obj(_)) => NetworkSpec::Custom(network_from_json(obj)?),
            _ => bail!("request needs a 'network': a builtin name or a topology object"),
        };
        let policy = match j.get("policy") {
            Some(p) => PrecisionPolicy::from_json(p).context("parsing 'policy'")?,
            None => PrecisionPolicy::paper(),
        };
        let gemms = match j.get("gemms") {
            Some(g) => gemms_from_json(g)?,
            None => Gemm::ALL.to_vec(),
        };
        Ok(AdvisorRequest {
            network,
            policy,
            gemms,
        })
    }
}

/// Run one advisory per builtin network named by a CLI-style selector
/// (`"all"` expands to the paper's three benchmarks).
pub fn advise_builtin(name: &str, policy: &PrecisionPolicy) -> Result<Vec<AdvisorReport>> {
    let mut out = Vec::new();
    for key in builtin_keys(name)? {
        out.push(AdvisorRequest::builtin(key, policy.clone()).run()?);
    }
    Ok(out)
}

/// The advisory answer: per-layer and per-group `(normal, chunked)`
/// minimum accumulator mantissa widths. The underlying
/// [`NetworkPrediction`] already reflects the request's GEMM narrowing
/// (filtered GEMMs are absent from its maps, not `N/A`).
#[derive(Clone, Debug)]
pub struct AdvisorReport {
    pub gemms: Vec<Gemm>,
    pub prediction: NetworkPrediction,
}

impl AdvisorReport {
    /// The analyzed network's display name.
    pub fn network(&self) -> &str {
        &self.prediction.network
    }

    /// Chunk size of the chunked column.
    pub fn chunk(&self) -> usize {
        self.prediction.chunk
    }

    /// Render the Table-1 style text table (identical to the pre-`api`
    /// CLI output when all three GEMMs are requested).
    pub fn render(&self) -> String {
        self.prediction.render()
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("type", "advisor_report");
        j.set("network", self.network());
        j.set("chunk", self.chunk());
        j.set("gemms", gemms_to_json(&self.gemms));
        let layers: Vec<Json> = self
            .prediction
            .layers
            .iter()
            .map(|lp| {
                let mut l = Json::obj();
                l.set("layer", lp.layer.as_str());
                l.set("group", lp.group.as_str());
                let mut lens = Json::obj();
                lens.set("fwd", lp.lengths.fwd);
                lens.set("bwd", lp.lengths.bwd);
                lens.set("grad", lp.lengths.grad);
                l.set("lengths", lens);
                l.set("gemms", per_gemm_to_json(&lp.per_gemm));
                l
            })
            .collect();
        j.set("layers", Json::Arr(layers));
        let groups: Vec<Json> = self
            .prediction
            .groups
            .iter()
            .map(|(g, agg)| {
                let mut o = Json::obj();
                o.set("group", g.as_str());
                o.set("gemms", per_gemm_to_json(agg));
                o
            })
            .collect();
        j.set("groups", Json::Arr(groups));
        j
    }

    pub fn from_json(j: &Json) -> Result<AdvisorReport> {
        let network = j
            .get("network")
            .and_then(Json::as_str)
            .context("report missing 'network'")?
            .to_string();
        let chunk = j
            .get("chunk")
            .and_then(Json::as_f64)
            .context("report missing 'chunk'")? as usize;
        let gemms = match j.get("gemms") {
            Some(g) => gemms_from_json(g)?,
            None => Gemm::ALL.to_vec(),
        };
        let mut layers = Vec::new();
        for l in j
            .get("layers")
            .and_then(Json::as_arr)
            .context("report missing 'layers'")?
        {
            let lens = l.get("lengths").context("layer missing 'lengths'")?;
            let len_of = |k: &str| -> Result<usize> {
                Ok(lens
                    .get(k)
                    .and_then(Json::as_f64)
                    .with_context(|| format!("layer lengths missing '{k}'"))?
                    as usize)
            };
            layers.push(LayerPrediction {
                layer: l
                    .get("layer")
                    .and_then(Json::as_str)
                    .context("layer missing 'layer'")?
                    .to_string(),
                group: l
                    .get("group")
                    .and_then(Json::as_str)
                    .context("layer missing 'group'")?
                    .to_string(),
                per_gemm: per_gemm_from_json(l.get("gemms").context("layer missing 'gemms'")?)?,
                lengths: AccumLengths {
                    fwd: len_of("fwd")?,
                    bwd: len_of("bwd")?,
                    grad: len_of("grad")?,
                },
            });
        }
        let mut groups = Vec::new();
        for g in j
            .get("groups")
            .and_then(Json::as_arr)
            .context("report missing 'groups'")?
        {
            groups.push((
                g.get("group")
                    .and_then(Json::as_str)
                    .context("group missing 'group'")?
                    .to_string(),
                per_gemm_from_json(g.get("gemms").context("group missing 'gemms'")?)?,
            ));
        }
        Ok(AdvisorReport {
            gemms,
            prediction: NetworkPrediction {
                network,
                chunk,
                layers,
                groups,
            },
        })
    }
}

fn gemms_to_json(gemms: &[Gemm]) -> Json {
    Json::Arr(gemms.iter().map(|g| Json::from(g.name())).collect())
}

fn gemms_from_json(j: &Json) -> Result<Vec<Gemm>> {
    let arr = match j.as_arr() {
        Some(a) => a,
        None => bail!("'gemms' must be an array of \"FWD\"/\"BWD\"/\"GRAD\""),
    };
    let mut out = Vec::new();
    for g in arr {
        let name = g.as_str().context("'gemms' entries must be strings")?;
        out.push(
            Gemm::from_name(name)
                .with_context(|| format!("unknown GEMM '{name}' (FWD|BWD|GRAD)"))?,
        );
    }
    Ok(out)
}

type PerGemm = std::collections::BTreeMap<&'static str, Option<Prediction>>;

fn per_gemm_to_json(map: &PerGemm) -> Json {
    let mut j = Json::obj();
    for (name, pred) in map {
        j.set(
            name,
            match pred {
                None => Json::Null,
                Some(p) => {
                    let mut o = Json::obj();
                    o.set("normal", p.normal);
                    o.set("chunked", p.chunked);
                    o
                }
            },
        );
    }
    j
}

fn per_gemm_from_json(j: &Json) -> Result<PerGemm> {
    let obj = match j {
        Json::Obj(m) => m,
        _ => bail!("'gemms' predictions must be an object"),
    };
    let mut out = PerGemm::new();
    for (name, pred) in obj {
        let gemm = Gemm::from_name(name)
            .with_context(|| format!("unknown GEMM key '{name}' (FWD|BWD|GRAD)"))?;
        let value = match pred {
            Json::Null => None,
            p => Some(Prediction {
                normal: p
                    .get("normal")
                    .and_then(Json::as_f64)
                    .context("prediction missing 'normal'")? as u32,
                chunked: p
                    .get("chunked")
                    .and_then(Json::as_f64)
                    .context("prediction missing 'chunked'")? as u32,
            }),
        };
        out.insert(gemm.name(), value);
    }
    Ok(out)
}

fn network_to_json(net: &Network) -> Json {
    let mut j = Json::obj();
    j.set("name", net.name.as_str());
    j.set("batch", net.batch);
    j.set("first_layer", net.first_layer);
    let layers: Vec<Json> = net
        .layers
        .iter()
        .map(|l| {
            let mut o = Json::obj();
            o.set(
                "kind",
                match l.kind {
                    LayerKind::Conv => "conv",
                    LayerKind::Fc => "fc",
                },
            );
            o.set("name", l.name.as_str());
            o.set("group", l.group.as_str());
            o.set("c_in", l.c_in);
            o.set("c_out", l.c_out);
            o.set("kernel", l.kernel);
            o.set("h_out", l.h_out);
            o.set("w_out", l.w_out);
            o
        })
        .collect();
    j.set("layers", Json::Arr(layers));
    j
}

fn network_from_json(j: &Json) -> Result<Network> {
    let layers_json = j
        .get("layers")
        .and_then(Json::as_arr)
        .context("custom network needs a 'layers' array")?;
    let mut layers = Vec::new();
    for (idx, l) in layers_json.iter().enumerate() {
        layers.push(layer_from_json(l, idx)?);
    }
    Ok(Network {
        name: j
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or("custom")
            .to_string(),
        batch: super::opt_num(j, "batch")?.unwrap_or(256.0) as usize,
        first_layer: super::opt_num(j, "first_layer")?.unwrap_or(0.0) as usize,
        layers,
    })
}

fn layer_from_json(j: &Json, idx: usize) -> Result<Layer> {
    let dim = |k: &str| -> Result<usize> {
        Ok(super::opt_num(j, k)
            .with_context(|| format!("layer {idx}"))?
            .with_context(|| format!("layer {idx} missing '{k}'"))? as usize)
    };
    let name = j
        .get("name")
        .and_then(Json::as_str)
        .map(str::to_string)
        .unwrap_or_else(|| format!("layer{idx}"));
    let group = j
        .get("group")
        .and_then(Json::as_str)
        .map(str::to_string)
        .unwrap_or_else(|| format!("Layer {idx}"));
    match j.get("kind").and_then(Json::as_str) {
        Some("conv") => {
            let h_out = dim("h_out")?;
            let w_out = super::opt_num(j, "w_out")?.map(|v| v as usize);
            Ok(Layer::conv(
                &name,
                &group,
                dim("c_in")?,
                dim("c_out")?,
                dim("kernel")?,
                h_out,
                w_out.unwrap_or(h_out),
            ))
        }
        Some("fc") => Ok(Layer::fc(&name, &group, dim("c_in")?, dim("c_out")?)),
        Some(other) => bail!("layer {idx}: unknown kind '{other}' (conv|fc)"),
        None => bail!("layer {idx}: missing 'kind' (conv|fc)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_report_matches_uncached_prediction() {
        let report = AdvisorRequest::builtin("resnet32", PrecisionPolicy::paper())
            .run()
            .unwrap();
        let direct = crate::nets::predict::predict_network(
            &resnet32_cifar10(),
            &NzrModel::resnet_default(),
            5,
            64,
        );
        assert_eq!(report.render(), direct.render());
        assert_eq!(report.chunk(), 64);
    }

    #[test]
    fn gemm_filter_narrows_report() {
        let mut req = AdvisorRequest::builtin("resnet32", PrecisionPolicy::paper());
        req.gemms = vec![Gemm::Grad];
        let report = req.run().unwrap();
        assert!(report.render().contains("GRAD"));
        assert!(!report.render().contains("FWD"));
        for lp in &report.prediction.layers {
            assert_eq!(lp.per_gemm.len(), 1);
        }
    }

    #[test]
    fn unknown_builtin_is_an_error() {
        assert!(AdvisorRequest::builtin("vgg", PrecisionPolicy::paper())
            .run()
            .is_err());
        assert!(builtin_keys("nope").is_err());
        assert_eq!(builtin_keys("all").unwrap().len(), 3);
    }

    #[test]
    fn custom_network_roundtrip() {
        let net = Network {
            name: "custom".into(),
            batch: 128,
            first_layer: 0,
            layers: vec![
                Layer::conv("conv0", "Stem", 3, 64, 7, 56, 56),
                Layer::fc("fc", "Head", 2048, 1000),
            ],
        };
        let req = AdvisorRequest::custom(net, PrecisionPolicy::paper().with_chunk(Some(32)));
        let text = req.to_json().to_string();
        let back = AdvisorRequest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string(), text);
        let report = back.run().unwrap();
        assert_eq!(report.chunk(), 32);
        assert_eq!(report.prediction.layers.len(), 2);
    }
}
