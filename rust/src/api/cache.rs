//! Memoized VRR solving.
//!
//! Every `min_m_acc` query binary-searches suitability, and every
//! suitability test evaluates Theorem 1's O(n) crossing sums — so a
//! Table-1 sweep (three networks × layers × GEMMs × {normal, chunked})
//! re-pays the same O(n) evaluations over and over, and a batch `serve`
//! workload pays them once per request. [`SolveCache`] memoizes both the
//! solver result (keyed on the full [`AccumSpec`]: `(n, m_p, nzr,
//! chunk)`) and individual VRR evaluations (additionally keyed on
//! `m_acc`). Cached values are **bit-identical** to direct evaluation —
//! the cache stores the solver's own output, it never recomputes —
//! which `rust/tests/api.rs` pins down across a parameter grid.
//!
//! A process-wide instance backs the `api` entry points ([`min_m_acc`],
//! [`vrr`]); independent instances ([`SolveCache::new`]) serve tests and
//! benchmarks that need cold-cache behaviour.
//!
//! ## Telemetry
//!
//! The global cache exports `abws_cache_{hits,misses,evictions}_total`
//! and `abws_cache_{solve,vrr}_entries` through a snapshot-time
//! [`crate::telemetry`] collector — the hot path keeps touching only the
//! cache's own relaxed atomics, with no duplicate bookkeeping. Lock
//! acquisition wait is sampled (1 in 64 queries) into
//! `abws_cache_lock_wait_ns` on instrumented instances.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use crate::telemetry::{self, Histogram, Timer};
use crate::vrr::solver::{self, AccumSpec};

/// Hashable image of an [`AccumSpec`] (`nzr` by its bit pattern; `chunk`
/// `None` encoded as 0, which no valid chunked spec uses).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct SpecKey {
    n: usize,
    m_p: u32,
    nzr_bits: u64,
    chunk: usize,
}

impl SpecKey {
    fn of(spec: &AccumSpec) -> SpecKey {
        SpecKey {
            n: spec.n,
            m_p: spec.m_p,
            nzr_bits: spec.nzr.to_bits(),
            chunk: spec.chunk.unwrap_or(0),
        }
    }
}

/// Hit/miss/size counters of a [`SolveCache`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Number of at-capacity table flushes (each drops a whole table).
    pub evictions: u64,
    pub solve_entries: usize,
    pub vrr_entries: usize,
}

/// Memoization table for [`solver::min_m_acc`] and [`AccumSpec::vrr`].
///
/// Thread-safe; concurrent misses on the same key may both compute, but
/// both compute the same deterministic value, so last-insert-wins is
/// harmless.
#[derive(Default)]
pub struct SolveCache {
    solve: Mutex<HashMap<SpecKey, u32>>,
    /// VRR values stored as `f64` bits so lookups are exactly the
    /// computed value (no float round-trip ambiguity).
    vrr: Mutex<HashMap<(SpecKey, u32), u64>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    /// When set ([`SolveCache::instrumented`]), lock acquisition wait is
    /// sampled into this histogram.
    lock_wait: Option<Arc<Histogram>>,
}

/// Per-table entry cap. The cache backs a long-running `serve` process
/// fed arbitrary custom topologies, so it must not grow without bound;
/// at the cap the table is flushed (simple, contention-free, and the
/// steady-state benchmark workloads fit in a small fraction of it).
pub const MAX_ENTRIES: usize = 1 << 16;

/// Sample 1 out of this many queries for lock-wait timing; keeps the
/// `Instant` syscall off 63/64 of the hot path.
const LOCK_WAIT_SAMPLE: u64 = 64;

impl SolveCache {
    pub fn new() -> SolveCache {
        SolveCache::default()
    }

    /// A cache whose lock-acquisition wait is sampled into the global
    /// `abws_cache_lock_wait_ns` histogram (used by the process-wide
    /// instance).
    pub fn instrumented() -> SolveCache {
        SolveCache {
            lock_wait: Some(telemetry::histogram("abws_cache_lock_wait_ns")),
            ..SolveCache::default()
        }
    }

    /// Lock `m`, sampling the wait time on roughly 1 in
    /// [`LOCK_WAIT_SAMPLE`] queries of instrumented caches.
    fn locked<'a, T>(&self, m: &'a Mutex<T>) -> MutexGuard<'a, T> {
        if let Some(hist) = &self.lock_wait {
            if telemetry::enabled() {
                let queries = self
                    .hits
                    .load(Ordering::Relaxed)
                    .wrapping_add(self.misses.load(Ordering::Relaxed));
                if queries % LOCK_WAIT_SAMPLE == 0 {
                    let t = Timer::start();
                    let guard = m.lock().unwrap();
                    hist.record(t.elapsed_ns());
                    return guard;
                }
            }
        }
        m.lock().unwrap()
    }

    /// Memoized [`solver::min_m_acc`].
    pub fn min_m_acc(&self, spec: &AccumSpec) -> u32 {
        let _span = if telemetry::trace::enabled() {
            telemetry::trace::TraceSpan::enter("cache.min_m_acc").attr("n", spec.n.to_string())
        } else {
            telemetry::trace::TraceSpan::noop()
        };
        let key = SpecKey::of(spec);
        if let Some(&m) = self.locked(&self.solve).get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return m;
        }
        // Compute outside the lock: solves take O(n log m_acc), and
        // sweeps call in from many threads.
        let m = solver::min_m_acc(spec);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut table = self.solve.lock().unwrap();
        if table.len() >= MAX_ENTRIES {
            table.clear();
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        table.insert(key, m);
        m
    }

    /// Memoized [`AccumSpec::vrr`] at accumulator width `m_acc`.
    pub fn vrr(&self, spec: &AccumSpec, m_acc: u32) -> f64 {
        let key = (SpecKey::of(spec), m_acc);
        if let Some(&bits) = self.locked(&self.vrr).get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return f64::from_bits(bits);
        }
        let v = spec.vrr(m_acc);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut table = self.vrr.lock().unwrap();
        if table.len() >= MAX_ENTRIES {
            table.clear();
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        table.insert(key, v.to_bits());
        v
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            solve_entries: self.solve.lock().unwrap().len(),
            vrr_entries: self.vrr.lock().unwrap().len(),
        }
    }

    pub fn clear(&self) {
        self.solve.lock().unwrap().clear();
        self.vrr.lock().unwrap().clear();
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
        self.evictions.store(0, Ordering::Relaxed);
    }
}

/// The process-wide cache behind the `api` entry points. Its counters
/// surface in telemetry snapshots as `abws_cache_*` (exported by a
/// collector, so the hot path carries no extra bookkeeping).
pub fn global() -> &'static SolveCache {
    static CACHE: OnceLock<SolveCache> = OnceLock::new();
    CACHE.get_or_init(|| {
        telemetry::register_collector(Arc::new(|snap| {
            let s = global().stats();
            snap.counters
                .insert("abws_cache_hits_total".into(), s.hits);
            snap.counters
                .insert("abws_cache_misses_total".into(), s.misses);
            snap.counters
                .insert("abws_cache_evictions_total".into(), s.evictions);
            snap.gauges
                .insert("abws_cache_solve_entries".into(), s.solve_entries as i64);
            snap.gauges
                .insert("abws_cache_vrr_entries".into(), s.vrr_entries as i64);
        }));
        SolveCache::instrumented()
    })
}

/// Memoized minimum accumulator width (process-wide cache).
pub fn min_m_acc(spec: &AccumSpec) -> u32 {
    global().min_m_acc(spec)
}

/// Memoized VRR evaluation (process-wide cache).
pub fn vrr(spec: &AccumSpec, m_acc: u32) -> f64 {
    global().vrr(spec, m_acc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn second_query_hits() {
        let cache = SolveCache::new();
        let spec = AccumSpec::plain(4096);
        let a = cache.min_m_acc(&spec);
        let b = cache.min_m_acc(&spec);
        assert_eq!(a, b);
        let s = cache.stats();
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, 1);
        assert_eq!(s.solve_entries, 1);
    }

    #[test]
    fn distinct_specs_do_not_collide() {
        let cache = SolveCache::new();
        let dense = AccumSpec::plain(1 << 15);
        let sparse = AccumSpec::plain(1 << 15).with_nzr(0.1);
        let chunked = AccumSpec::plain(1 << 15).with_chunk(64);
        let md = cache.min_m_acc(&dense);
        let ms = cache.min_m_acc(&sparse);
        let mc = cache.min_m_acc(&chunked);
        assert_eq!(md, solver::min_m_acc(&dense));
        assert_eq!(ms, solver::min_m_acc(&sparse));
        assert_eq!(mc, solver::min_m_acc(&chunked));
        assert_eq!(cache.stats().solve_entries, 3);
    }

    #[test]
    fn clear_resets() {
        let cache = SolveCache::new();
        cache.min_m_acc(&AccumSpec::plain(64));
        cache.clear();
        assert_eq!(cache.stats(), CacheStats::default());
    }

    #[test]
    fn instrumented_cache_matches_plain() {
        // Sampling depends on the global enabled flag; serialize with
        // tests that flip it.
        let _guard = telemetry::TEST_ENABLED_LOCK.lock().unwrap();
        telemetry::set_enabled(true);
        let cache = SolveCache::instrumented();
        let before = cache.lock_wait.as_ref().unwrap().count();
        let spec = AccumSpec::plain(4096).with_chunk(64);
        // Enough repeats to cross the 1-in-64 sampling boundary at least
        // once (query 0 always samples).
        for _ in 0..130 {
            assert_eq!(cache.min_m_acc(&spec), solver::min_m_acc(&spec));
        }
        let s = cache.stats();
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, 129);
        assert!(cache.lock_wait.as_ref().unwrap().count() > before);
    }

    #[test]
    fn global_cache_exports_through_collector() {
        // Touch the global cache, then check the collector contributed.
        min_m_acc(&AccumSpec::plain(777));
        let snap = telemetry::snapshot();
        let hits = snap.counters["abws_cache_hits_total"];
        let misses = snap.counters["abws_cache_misses_total"];
        assert!(misses >= 1);
        let s = global().stats();
        // Counters only move forward; the snapshot can lag concurrent
        // tests but never exceed the live values.
        assert!(s.hits >= hits);
        assert!(s.misses >= misses);
        assert!(snap.gauges.contains_key("abws_cache_solve_entries"));
        assert!(snap.counters.contains_key("abws_cache_evictions_total"));
    }
}
