//! `check` requests: guaranteed-overflow-avoidance style suitability
//! queries for a single accumulation.
//!
//! Where `advisor` answers "what widths does this whole network need",
//! `check` answers the pointwise question: for one length-`n`
//! accumulation under a policy, what is the minimum suitable `m_acc` —
//! and, if the client proposes a width, is *that* width suitable and
//! what variance retention does it achieve? All solving goes through the
//! process-wide memoized [`crate::api::cache`], so batches of checks hit
//! the fast path.

use anyhow::{ensure, Context, Result};

use super::cache;
use super::policy::PrecisionPolicy;
use crate::util::json::Json;

/// One suitability query: a policy, an accumulation length, a sparsity
/// (non-zero ratio), and optionally a proposed accumulator width.
#[derive(Clone, Debug)]
pub struct CheckRequest {
    pub policy: PrecisionPolicy,
    /// Accumulation length (dot-product length).
    pub n: usize,
    /// Non-zero ratio of the operands (1.0 = dense).
    pub nzr: f64,
    /// Proposed accumulator mantissa width to check, if any.
    pub m_acc: Option<u32>,
}

impl CheckRequest {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("type", "check");
        j.set("policy", self.policy.to_json());
        j.set("n", self.n);
        j.set("nzr", self.nzr);
        j.set("m_acc", self.m_acc.map(Json::from).unwrap_or(Json::Null));
        j
    }

    /// Parse the wire form. `n` is required; `nzr` defaults to dense
    /// (1.0); `m_acc` is optional; type-mismatched fields are errors.
    pub fn from_json(j: &Json) -> Result<CheckRequest> {
        let policy = match j.get("policy") {
            Some(p) => PrecisionPolicy::from_json(p).context("parsing 'policy'")?,
            None => PrecisionPolicy::paper(),
        };
        let n = super::opt_num(j, "n")?.context("check request needs 'n'")? as usize;
        let nzr = super::opt_num(j, "nzr")?.unwrap_or(1.0);
        let m_acc = super::opt_num(j, "m_acc")?.map(|v| v as u32);
        Ok(CheckRequest {
            policy,
            n,
            nzr,
            m_acc,
        })
    }

    /// Validate and answer through the memoized solver.
    pub fn run(&self) -> Result<CheckReport> {
        self.policy.validate()?;
        ensure!(
            (0.0..=1.0).contains(&self.nzr),
            "nzr must be in [0,1], got {}",
            self.nzr
        );
        if let Some(m) = self.m_acc {
            ensure!((1..=52).contains(&m), "m_acc must be in 1..=52, got {m}");
        }
        let spec = self.policy.checked_accum_spec(self.n, self.nzr)?;
        let min_m_acc = cache::min_m_acc(&spec);
        let proposed = self.m_acc.map(|m| {
            let vrr = cache::vrr(&spec, m);
            (spec.suitable(m), vrr)
        });
        Ok(CheckReport {
            n: self.n,
            nzr: self.nzr,
            m_acc: self.m_acc,
            min_m_acc,
            proposed,
        })
    }
}

/// The suitability answer for one accumulation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CheckReport {
    pub n: usize,
    pub nzr: f64,
    /// The proposed width echoed back, if the request carried one.
    pub m_acc: Option<u32>,
    /// Minimum suitable accumulator mantissa width (Theorem 1).
    pub min_m_acc: u32,
    /// `(suitable, vrr)` of the proposed width, if one was given.
    pub proposed: Option<(bool, f64)>,
}

impl CheckReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("type", "check_report");
        j.set("n", self.n);
        j.set("nzr", self.nzr);
        j.set("m_acc", self.m_acc.map(Json::from).unwrap_or(Json::Null));
        j.set("min_m_acc", self.min_m_acc);
        match self.proposed {
            Some((suitable, vrr)) => {
                j.set("suitable", suitable);
                // The chunked-VRR closed form can overflow to ±inf for
                // tiny widths; JSON has no Inf, so degrade to null.
                j.set(
                    "vrr",
                    if vrr.is_finite() {
                        Json::Num(vrr)
                    } else {
                        Json::Null
                    },
                );
            }
            None => {
                j.set("suitable", Json::Null);
                j.set("vrr", Json::Null);
            }
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_agrees_with_direct_solver() {
        let req = CheckRequest {
            policy: PrecisionPolicy::paper(),
            n: 4096,
            nzr: 1.0,
            m_acc: Some(12),
        };
        let report = req.run().unwrap();
        let spec = req.policy.accum_spec(4096, 1.0);
        assert_eq!(report.min_m_acc, crate::vrr::solver::min_m_acc(&spec));
        let (suitable, vrr) = report.proposed.unwrap();
        assert_eq!(suitable, spec.suitable(12));
        assert_eq!(vrr.to_bits(), spec.vrr(12).to_bits());
    }

    #[test]
    fn json_roundtrip() {
        let req = CheckRequest {
            policy: PrecisionPolicy::paper().with_chunk(Some(64)),
            n: 1000,
            nzr: 0.5,
            m_acc: Some(9),
        };
        let text = req.to_json().to_string();
        let back = CheckRequest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string(), text);
        assert_eq!(back.n, 1000);
        assert_eq!(back.m_acc, Some(9));
    }

    #[test]
    fn report_shape_without_proposed_width() {
        let req = CheckRequest {
            policy: PrecisionPolicy::paper(),
            n: 64,
            nzr: 1.0,
            m_acc: None,
        };
        let j = req.run().unwrap().to_json();
        assert_eq!(j.get("type").unwrap().as_str(), Some("check_report"));
        assert_eq!(j.get("m_acc"), Some(&Json::Null));
        assert_eq!(j.get("suitable"), Some(&Json::Null));
        assert!(j.get("min_m_acc").unwrap().as_f64().unwrap() >= 1.0);
    }

    #[test]
    fn rejects_nonsense() {
        let mut req = CheckRequest {
            policy: PrecisionPolicy::paper(),
            n: 64,
            nzr: 1.0,
            m_acc: None,
        };
        req.nzr = 1.5;
        assert!(req.run().is_err());
        req.nzr = 1.0;
        req.m_acc = Some(0);
        assert!(req.run().is_err());
        req.m_acc = None;
        req.n = 0;
        assert!(req.run().is_err());
        // n required on the wire.
        assert!(CheckRequest::from_json(&Json::parse(r#"{"type":"check"}"#).unwrap()).is_err());
    }
}
