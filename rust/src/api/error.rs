//! The one error shape every `api` failure path speaks: an [`ApiError`]
//! carrying a machine-readable [`ErrorKind`] plus a human message.
//!
//! `serve` renders failures as
//! `{"error": {"kind": "...", "message": "..."}}` lines (plus a
//! deprecated top-level `"message"` string kept for one release — see
//! `docs/serve.md`), so batch clients can switch on `kind` instead of
//! grepping prose:
//!
//! * `parse` — the request line is not valid JSON;
//! * `invalid` — well-formed JSON but a bad request (unknown type,
//!   unknown network, type-mismatched field, unsupported envelope
//!   version, policy that fails validation);
//! * `timeout` — the request exceeded its `--timeout-ms` deadline;
//! * `panic` — the handler panicked (isolated by the serve pipeline;
//!   the batch keeps going);
//! * `internal` — anything else that went wrong while executing an
//!   otherwise valid request.

use std::fmt;

use crate::util::json::Json;

/// Machine-readable failure category, serialized as the `"kind"` field
/// of every serve error line.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorKind {
    /// Request line is not valid JSON.
    Parse,
    /// Valid JSON, invalid request.
    Invalid,
    /// The request exceeded its deadline.
    Timeout,
    /// The handler panicked.
    Panic,
    /// Execution failed on a valid request.
    Internal,
}

impl ErrorKind {
    /// The wire spelling (`"parse"`, `"invalid"`, …).
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorKind::Parse => "parse",
            ErrorKind::Invalid => "invalid",
            ErrorKind::Timeout => "timeout",
            ErrorKind::Panic => "panic",
            ErrorKind::Internal => "internal",
        }
    }
}

impl fmt::Display for ErrorKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A kinded API failure. Implements [`std::error::Error`], so it
/// converts into `anyhow::Error` via `?` where callers still speak
/// `anyhow`.
#[derive(Clone, Debug)]
pub struct ApiError {
    pub kind: ErrorKind,
    pub message: String,
}

impl ApiError {
    pub fn new(kind: ErrorKind, message: impl Into<String>) -> ApiError {
        ApiError {
            kind,
            message: message.into(),
        }
    }

    pub fn parse(message: impl Into<String>) -> ApiError {
        ApiError::new(ErrorKind::Parse, message)
    }

    pub fn invalid(message: impl Into<String>) -> ApiError {
        ApiError::new(ErrorKind::Invalid, message)
    }

    pub fn timeout(message: impl Into<String>) -> ApiError {
        ApiError::new(ErrorKind::Timeout, message)
    }

    pub fn panic(message: impl Into<String>) -> ApiError {
        ApiError::new(ErrorKind::Panic, message)
    }

    pub fn internal(message: impl Into<String>) -> ApiError {
        ApiError::new(ErrorKind::Internal, message)
    }

    /// The `{"kind": ..., "message": ...}` object serve embeds under
    /// `"error"`.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("kind", self.kind.as_str());
        j.set("message", self.message.as_str());
        j
    }
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.kind, self.message)
    }
}

impl std::error::Error for ApiError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_have_stable_wire_names() {
        let kinds = [
            (ErrorKind::Parse, "parse"),
            (ErrorKind::Invalid, "invalid"),
            (ErrorKind::Timeout, "timeout"),
            (ErrorKind::Panic, "panic"),
            (ErrorKind::Internal, "internal"),
        ];
        for (k, name) in kinds {
            assert_eq!(k.as_str(), name);
        }
    }

    #[test]
    fn json_shape_carries_kind_and_message() {
        let e = ApiError::timeout("deadline exceeded after 12 steps");
        let j = e.to_json();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("timeout"));
        assert_eq!(
            j.get("message").unwrap().as_str(),
            Some("deadline exceeded after 12 steps")
        );
        assert_eq!(format!("{e}"), "timeout: deadline exceeded after 12 steps");
    }

    #[test]
    fn converts_into_anyhow() {
        fn fails() -> anyhow::Result<()> {
            Err(ApiError::invalid("bad policy"))?
        }
        let e = fails().unwrap_err();
        assert!(format!("{e:#}").contains("bad policy"));
    }
}
