//! `test` requests: empirical Monte-Carlo VRR measurement over a sweep
//! of accumulator widths.
//!
//! Where [`super::check`] answers from the closed-form theory, `test`
//! actually *runs* the bit-accurate simulator: draw an ensemble of
//! reduced-precision accumulations and measure the variance retention at
//! every requested `m_acc` — the experiment behind Fig. 5. The whole
//! width sweep goes through one [`crate::mc::engine::sweep_vrr`] call,
//! so the ensemble is drawn once and shared across all sweep points, and
//! each measured value is bit-identical to a single-config run with the
//! same seed.

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::sweep::default_threads;
use crate::mc::engine::{sweep_vrr, AccumSetup, Ensemble};
use crate::softfloat::quant::Rounding;
use crate::util::json::Json;
use crate::vrr::chunking::vrr_chunked_total;
use crate::vrr::theorem::vrr as vrr_theory;

/// Ceilings that keep one serve line from monopolizing the process: a
/// full request is at most `trials * n * len(m_accs)` accumulation steps.
const MAX_TRIALS: usize = 4_096;
const MAX_N: usize = 1 << 22;
const MAX_WIDTHS: usize = 64;

/// One empirical sweep request: measure the VRR of each width in
/// `m_accs` for a length-`n` accumulation, all against the same drawn
/// ensemble.
#[derive(Clone, Debug)]
pub struct TestRequest {
    /// Accumulation length.
    pub n: usize,
    /// Accumulator mantissa widths to sweep (grid order is reply order).
    pub m_accs: Vec<u32>,
    /// Product mantissa bits (terms are drawn pre-rounded to this).
    pub m_p: u32,
    /// Chunk size shared by every sweep point (`None` = plain).
    pub chunk: Option<usize>,
    /// Accumulation rounding mode shared by every sweep point.
    pub rounding: Rounding,
    /// Ensemble size.
    pub trials: usize,
    pub seed: u64,
}

impl TestRequest {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("type", "test");
        j.set("n", self.n);
        j.set(
            "m_accs",
            Json::Arr(self.m_accs.iter().map(|&m| Json::from(m)).collect()),
        );
        j.set("m_p", self.m_p);
        j.set("chunk", self.chunk.map(Json::from).unwrap_or(Json::Null));
        j.set(
            "rounding",
            match self.rounding {
                Rounding::NearestEven => "nearest_even",
                Rounding::TowardZero => "toward_zero",
            },
        );
        j.set("trials", self.trials);
        j.set("seed", self.seed);
        j
    }

    /// Parse the wire form. `n` is required; widths come from `m_accs`
    /// (array) or a scalar `m_acc`, one of which is required;
    /// type-mismatched fields are errors, never silent defaults.
    pub fn from_json(j: &Json) -> Result<TestRequest> {
        let n = super::opt_num(j, "n")?.context("test request needs 'n'")? as usize;
        let m_accs: Vec<u32> = match (j.get("m_accs"), j.get("m_acc")) {
            (Some(Json::Arr(items)), _) => items
                .iter()
                .map(|v| {
                    v.as_f64()
                        .map(|f| f as u32)
                        .with_context(|| format!("'m_accs' entries must be numbers, got {v}"))
                })
                .collect::<Result<_>>()?,
            (Some(other), _) => bail!("'m_accs' must be an array, got {other}"),
            (None, Some(_)) => vec![super::opt_num(j, "m_acc")?
                .context("'m_acc' must be a number")? as u32],
            (None, None) => bail!("test request needs 'm_accs' (array) or 'm_acc'"),
        };
        let m_p = super::opt_num(j, "m_p")?.map(|v| v as u32).unwrap_or(5);
        let chunk = super::opt_num(j, "chunk")?.map(|v| v as usize);
        let rounding = match j.get("rounding") {
            None | Some(Json::Null) => Rounding::NearestEven,
            Some(r) => match r.as_str() {
                Some("nearest_even") => Rounding::NearestEven,
                Some("toward_zero") => Rounding::TowardZero,
                _ => bail!("unknown rounding {r} (nearest_even|toward_zero)"),
            },
        };
        let trials = super::opt_num(j, "trials")?.map(|v| v as usize).unwrap_or(64);
        let seed = super::opt_num(j, "seed")?.map(|v| v as u64).unwrap_or(0x5eed);
        Ok(TestRequest {
            n,
            m_accs,
            m_p,
            chunk,
            rounding,
            trials,
            seed,
        })
    }

    /// Validate and run the sweep on the shared worker pool.
    pub fn run(&self) -> Result<TestReport> {
        ensure!(!self.m_accs.is_empty(), "test request needs at least one accumulator width");
        ensure!(
            self.m_accs.len() <= MAX_WIDTHS,
            "at most {MAX_WIDTHS} accumulator widths per test request, got {}",
            self.m_accs.len()
        );
        for &m in &self.m_accs {
            ensure!((1..=52).contains(&m), "m_acc must be in 1..=52, got {m}");
        }
        ensure!(
            (1..=52).contains(&self.m_p),
            "m_p must be in 1..=52, got {}",
            self.m_p
        );
        ensure!(self.n <= MAX_N, "n must be at most {MAX_N}, got {}", self.n);
        ensure!(
            self.trials <= MAX_TRIALS,
            "trials must be at most {MAX_TRIALS}, got {}",
            self.trials
        );
        if let Some(c) = self.chunk {
            ensure!(c >= 1, "chunk must be at least 1");
            ensure!(c <= self.n, "chunk {c} exceeds accumulation length {}", self.n);
        }
        // `trials < 2` / `n == 0` come back as structured McErrors; the
        // blanket From turns them into the serve error line.
        let ens = Ensemble {
            n: self.n,
            m_p: self.m_p,
            e_acc: 6,
            sigma_p: 1.0,
            trials: self.trials,
            seed: self.seed,
            threads: default_threads(),
        };
        let grid: Vec<AccumSetup> = self
            .m_accs
            .iter()
            .map(|&m| {
                let s = AccumSetup::new(m).with_rounding(self.rounding);
                match self.chunk {
                    Some(c) => s.with_chunk(c),
                    None => s,
                }
            })
            .collect();
        let measured = sweep_vrr(&ens, &grid)?;
        let points = self
            .m_accs
            .iter()
            .zip(&measured)
            .map(|(&m_acc, r)| TestPoint {
                m_acc,
                theory: match self.chunk {
                    Some(c) => vrr_chunked_total(m_acc, self.m_p, self.n, c),
                    None => vrr_theory(m_acc, self.m_p, self.n),
                },
                measured: r.vrr,
                var_swamping: r.var_swamping,
                var_ideal: r.var_ideal,
            })
            .collect();
        Ok(TestReport {
            n: self.n,
            m_p: self.m_p,
            chunk: self.chunk,
            rounding: self.rounding,
            trials: self.trials,
            seed: self.seed,
            points,
        })
    }
}

/// One measured sweep point.
#[derive(Clone, Copy, Debug)]
pub struct TestPoint {
    pub m_acc: u32,
    /// Closed-form VRR (Theorem 1 / Corollary 1) for comparison.
    pub theory: f64,
    /// Monte-Carlo measured VRR.
    pub measured: f64,
    pub var_swamping: f64,
    pub var_ideal: f64,
}

/// The empirical sweep answer: the request echoed back plus one measured
/// point per requested width, in request order.
#[derive(Clone, Debug)]
pub struct TestReport {
    pub n: usize,
    pub m_p: u32,
    pub chunk: Option<usize>,
    pub rounding: Rounding,
    pub trials: usize,
    pub seed: u64,
    pub points: Vec<TestPoint>,
}

/// JSON has no Inf/NaN; degrade to null (the chunked-VRR closed form can
/// overflow for tiny widths).
fn finite(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

impl TestReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("type", "test_report");
        j.set("n", self.n);
        j.set("m_p", self.m_p);
        j.set("chunk", self.chunk.map(Json::from).unwrap_or(Json::Null));
        j.set(
            "rounding",
            match self.rounding {
                Rounding::NearestEven => "nearest_even",
                Rounding::TowardZero => "toward_zero",
            },
        );
        j.set("trials", self.trials);
        j.set("seed", self.seed);
        let points = self
            .points
            .iter()
            .map(|p| {
                let mut o = Json::obj();
                o.set("m_acc", p.m_acc);
                o.set("theory", finite(p.theory));
                o.set("measured", finite(p.measured));
                o.set("var_swamping", finite(p.var_swamping));
                o.set("var_ideal", finite(p.var_ideal));
                o
            })
            .collect::<Vec<_>>();
        j.set("points", Json::Arr(points));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> Result<TestRequest> {
        TestRequest::from_json(&Json::parse(text).unwrap())
    }

    #[test]
    fn json_roundtrip() {
        let req = parse(
            r#"{"type":"test","n":2048,"m_accs":[5,8,12],"chunk":64,
                "rounding":"toward_zero","trials":32,"seed":9}"#,
        )
        .unwrap();
        assert_eq!(req.n, 2048);
        assert_eq!(req.m_accs, vec![5, 8, 12]);
        assert_eq!(req.chunk, Some(64));
        assert_eq!(req.rounding, Rounding::TowardZero);
        assert_eq!(req.trials, 32);
        let text = req.to_json().to_string();
        let back = parse(&text).unwrap();
        assert_eq!(back.to_json().to_string(), text);
    }

    #[test]
    fn scalar_m_acc_is_a_one_point_sweep() {
        let req = parse(r#"{"type":"test","n":256,"m_acc":8}"#).unwrap();
        assert_eq!(req.m_accs, vec![8]);
        assert_eq!(req.trials, 64);
        assert_eq!(req.rounding, Rounding::NearestEven);
    }

    #[test]
    fn rejects_malformed_requests() {
        assert!(parse(r#"{"type":"test","m_acc":8}"#).is_err()); // no n
        assert!(parse(r#"{"type":"test","n":256}"#).is_err()); // no widths
        assert!(parse(r#"{"type":"test","n":256,"m_accs":7}"#).is_err());
        assert!(parse(r#"{"type":"test","n":256,"m_accs":["x"]}"#).is_err());
        assert!(parse(r#"{"type":"test","n":256,"m_acc":8,"rounding":"up"}"#).is_err());
        assert!(parse(r#"{"type":"test","n":"big","m_acc":8}"#).is_err());
    }

    #[test]
    fn run_rejects_out_of_range() {
        let base = parse(r#"{"type":"test","n":256,"m_acc":8,"trials":8}"#).unwrap();
        let mut r = base.clone();
        r.m_accs = vec![0];
        assert!(r.run().is_err());
        let mut r = base.clone();
        r.m_accs.clear();
        assert!(r.run().is_err());
        let mut r = base.clone();
        r.trials = MAX_TRIALS + 1;
        assert!(r.run().is_err());
        let mut r = base.clone();
        r.chunk = Some(1024); // > n
        assert!(r.run().is_err());
        // Structured engine errors surface through run() too.
        let mut r = base.clone();
        r.trials = 1;
        assert!(r.run().unwrap_err().to_string().contains("at least 2"));
        let mut r = base;
        r.n = 0;
        assert!(r.run().is_err());
    }

    #[test]
    fn sweep_matches_single_config_oracle() {
        let req = parse(
            r#"{"type":"test","n":1024,"m_accs":[5,9],"chunk":32,"trials":24,"seed":3}"#,
        )
        .unwrap();
        let report = req.run().unwrap();
        assert_eq!(report.points.len(), 2);
        for p in &report.points {
            let want = crate::mc::empirical_vrr_ref(
                &crate::mc::McConfig::new(1024, p.m_acc)
                    .with_chunk(32)
                    .with_trials(24)
                    .with_seed(3),
            );
            assert_eq!(p.measured.to_bits(), want.vrr.to_bits());
        }
        let j = report.to_json();
        assert_eq!(j.get("type").unwrap().as_str(), Some("test_report"));
        assert_eq!(j.get("points").unwrap().as_arr().unwrap().len(), 2);
    }
}
