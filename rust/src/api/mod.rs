//! `abws::api` — the typed entry point to the whole analysis stack.
//!
//! The paper's punchline is a *service*: feed in layer shapes, get back
//! the minimum accumulator widths without brute-force emulation. This
//! module is that service boundary:
//!
//! * [`PrecisionPolicy`] (in [`policy`]) — the one precision
//!   configuration type, replacing hand-assembled
//!   `AccumSpec`/`GemmConfig`/`PrecisionPlan`/`NzrModel` quadruples.
//! * [`AdvisorRequest`] → [`AdvisorReport`] (in [`advisor`]) — per-layer
//!   and per-group minimum accumulator widths for a builtin or custom
//!   network, with JSON encode/decode.
//! * [`TrainRequest`] → [`TrainReport`](train::TrainReport) (in
//!   [`train`]) — native reduced-precision training runs under a
//!   baseline / uniform / solver-predicted plan.
//! * [`CheckRequest`] → [`CheckReport`](check::CheckReport) (in
//!   [`check`]) — pointwise suitability queries: minimum `m_acc` for one
//!   accumulation, plus suitability/VRR of a proposed width.
//! * [`TestRequest`] → [`TestReport`](mctest::TestReport) (in
//!   [`mctest`]) — empirical Monte-Carlo VRR sweeps over accumulator
//!   widths, run through the sweep-vectorized `mc::engine` so one drawn
//!   ensemble serves every width.
//! * [`cache`] — the memoized VRR solve cache all API queries share, so
//!   repeated `min_m_acc` sweeps stop re-running the O(n) crossing sums.
//! * [`error`] — the unified [`ApiError`]/[`ErrorKind`] failure shape
//!   every serve error line carries.
//! * [`serve`] — the batch front-end: newline-delimited JSON requests in,
//!   one JSON report per line out (`abws serve` on the CLI). [`serve_with`]
//!   runs the same batch through a pooled pipeline with ordered replies,
//!   backpressure, per-request deadlines and panic isolation.
//!
//! ```no_run
//! use abws::api::{AdvisorRequest, PrecisionPolicy};
//!
//! let report = AdvisorRequest::builtin("resnet18", PrecisionPolicy::paper())
//!     .run()
//!     .unwrap();
//! println!("{}", report.render());
//! ```

pub mod advisor;
pub mod cache;
pub mod check;
pub mod error;
pub mod mctest;
pub mod policy;
pub mod serve;
pub mod train;

pub use advisor::{advise_builtin, builtin_keys, AdvisorReport, AdvisorRequest, NetworkSpec};
pub use check::{CheckReport, CheckRequest};
pub use error::{ApiError, ErrorKind};
pub use mctest::{TestReport, TestRequest};
pub use policy::{baseline_plan, fp8_ideal_acc_plan, PrecisionPolicy, PrecisionPolicyBuilder};
pub use serve::{default_workers, serve, serve_with, ServeOptions, ServeStats};
pub use train::{PlanSpec, TrainReport, TrainRequest};

/// Strict optional-number accessor for the request codecs: absent or
/// `null` is `None`, a number is `Some`, anything else is an error — a
/// type-mismatched field must never silently fall back to a default
/// (a `serve` client that sends `"steps": "100"` should get an error
/// line, not a 300-step run).
pub(crate) fn opt_num(
    j: &crate::util::json::Json,
    key: &str,
) -> anyhow::Result<Option<f64>> {
    use crate::util::json::Json;
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Num(v)) => Ok(Some(*v)),
        Some(other) => anyhow::bail!("'{key}' must be a number, got {other}"),
    }
}
