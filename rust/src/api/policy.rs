//! [`PrecisionPolicy`] — the one precision configuration type.
//!
//! Before the `api` layer, every driver hand-assembled four overlapping
//! configs: an [`AccumSpec`] for the VRR solver, a [`GemmConfig`] for the
//! softfloat simulator, a [`PrecisionPlan`] for the trainer and an
//! [`NzrModel`] for the sparsity correction — each with its own copy of
//! the paper defaults. `PrecisionPolicy` holds those defaults once
//! (representation/product/accumulator formats, chunking, rounding,
//! sparsity) and derives each downstream config on demand.

use anyhow::{bail, Result};

use crate::nets::nzr::{NzrModel, NzrTriple};
use crate::softfloat::format::FpFormat;
use crate::softfloat::gemm::GemmConfig;
use crate::softfloat::quant::Rounding;
use crate::trainer::native::PrecisionPlan;
use crate::util::json::Json;
use crate::vrr::solver::AccumSpec;

/// Chunk size of the advisor's "chunked" column when the policy does not
/// pin one (the paper's chunk-64 accumulation).
pub const DEFAULT_ADVISOR_CHUNK: usize = 64;

/// Unified precision configuration for analysis and simulation.
///
/// One `PrecisionPolicy` answers every configuration question the stack
/// asks: what the operands are quantized to ([`Self::repr`]), how exact
/// the products are ([`Self::prod`], [`Self::m_p`]), what the accumulator
/// format is ([`Self::acc_exp_bits`] plus a per-query mantissa width),
/// whether accumulation is chunked ([`Self::chunk`]), how mantissas are
/// rounded ([`Self::rounding`]) and how sparse the operands are
/// ([`Self::nzr`]).
#[derive(Clone, Debug)]
pub struct PrecisionPolicy {
    /// Representation format quantizing GEMM *inputs* (`None` = keep f32).
    pub repr: Option<FpFormat>,
    /// Product-term format (paper: the exact (1,6,5) product of two
    /// (1,5,2) values).
    pub prod: FpFormat,
    /// Accumulator exponent bits (paper §5: 6).
    pub acc_exp_bits: u32,
    /// Product mantissa width used by the VRR analysis (5 for (1,5,2)
    /// inputs).
    pub m_p: u32,
    /// Chunk size for two-level accumulation (`None` = sequential).
    pub chunk: Option<usize>,
    /// Mantissa rounding mode of the simulated datapath.
    pub rounding: Rounding,
    /// Sparsity model; `None` means "use the network's calibrated default
    /// (built-ins) or the ReLU default `(1.0, 0.5, 0.5)` (custom nets)".
    pub nzr: Option<NzrModel>,
}

impl Default for PrecisionPolicy {
    fn default() -> Self {
        PrecisionPolicy::paper()
    }
}

impl PrecisionPolicy {
    /// The paper's configuration: (1,5,2) inputs, exact 5-bit products,
    /// `(1,6,m_acc)` accumulators, round-to-nearest-even, sequential
    /// accumulation, network-default sparsity.
    pub fn paper() -> PrecisionPolicy {
        PrecisionPolicy {
            repr: Some(FpFormat::FP8_152),
            prod: FpFormat::PROD_FP8,
            acc_exp_bits: 6,
            m_p: 5,
            chunk: None,
            rounding: Rounding::NearestEven,
            nzr: None,
        }
    }

    /// Start a [`PrecisionPolicyBuilder`] from the paper defaults.
    /// Unlike the `with_*` combinators, the builder validates at
    /// [`PrecisionPolicyBuilder::build`], so invalid configurations fail
    /// before they ever reach the solver.
    pub fn builder() -> PrecisionPolicyBuilder {
        PrecisionPolicyBuilder::default()
    }

    pub fn with_chunk(mut self, chunk: Option<usize>) -> PrecisionPolicy {
        self.chunk = chunk;
        self
    }

    pub fn with_m_p(mut self, m_p: u32) -> PrecisionPolicy {
        self.m_p = m_p;
        self
    }

    pub fn with_nzr(mut self, nzr: NzrModel) -> PrecisionPolicy {
        self.nzr = Some(nzr);
        self
    }

    pub fn with_rounding(mut self, rounding: Rounding) -> PrecisionPolicy {
        self.rounding = rounding;
        self
    }

    /// Check the policy is physically meaningful before analysis.
    pub fn validate(&self) -> Result<()> {
        if self.m_p == 0 || self.m_p > 52 {
            bail!("policy.m_p must be in 1..=52, got {}", self.m_p);
        }
        if !(2..=11).contains(&self.acc_exp_bits) {
            bail!(
                "policy.acc_exp_bits must be in 2..=11, got {}",
                self.acc_exp_bits
            );
        }
        if let Some(c) = self.chunk {
            if c == 0 {
                bail!("policy.chunk must be >= 1 (use null for sequential accumulation)");
            }
        }
        if let Some(m) = &self.nzr {
            let mut triples = vec![("default", m.default)];
            for (g, t) in &m.per_group {
                triples.push((g.as_str(), *t));
            }
            for (label, t) in triples {
                for v in [t.fwd, t.bwd, t.grad] {
                    if !(0.0..=1.0).contains(&v) {
                        bail!("policy.nzr[{label}] out of [0,1]: {v}");
                    }
                }
            }
        }
        Ok(())
    }

    /// The VRR solver description of one length-`n` accumulation under
    /// this policy.
    pub fn accum_spec(&self, n: usize, nzr: f64) -> AccumSpec {
        AccumSpec {
            n,
            m_p: self.m_p,
            nzr,
            chunk: self.chunk,
        }
    }

    /// [`Self::accum_spec`] for callers with an *explicit* accumulation
    /// length (`check` requests, `abws vrr`): rejects zero-length
    /// accumulations and chunks longer than the accumulation itself,
    /// which the closed forms would silently accept and answer
    /// nonsensically. The implicit-length paths (advisor, trainer) keep
    /// using `accum_spec` directly, where a policy chunk larger than one
    /// particular GEMM dimension legitimately degrades to sequential.
    pub fn checked_accum_spec(&self, n: usize, nzr: f64) -> Result<AccumSpec> {
        if n == 0 {
            bail!("zero-length accumulation (n must be >= 1)");
        }
        if let Some(c) = self.chunk {
            if c > n {
                bail!("chunk {c} is larger than the accumulation length {n}");
            }
        }
        Ok(self.accum_spec(n, nzr))
    }

    /// The softfloat GEMM configuration at accumulator width `m_acc`.
    pub fn gemm_config(&self, m_acc: u32) -> GemmConfig {
        GemmConfig {
            repr: self.repr,
            prod: self.prod,
            acc: FpFormat::new(self.acc_exp_bits, m_acc),
            chunk: self.chunk,
            mode: self.rounding,
        }
    }

    /// Trainer plan with one accumulator width for all three GEMMs.
    pub fn plan_uniform(&self, m_acc: u32) -> PrecisionPlan {
        let cfg = self.gemm_config(m_acc);
        PrecisionPlan {
            fwd: cfg,
            bwd: cfg,
            grad: cfg,
        }
    }

    /// Trainer plan with per-GEMM accumulator widths (the Table-1 shape).
    pub fn plan_per_gemm(&self, fwd: u32, bwd: u32, grad: u32) -> PrecisionPlan {
        PrecisionPlan {
            fwd: self.gemm_config(fwd),
            bwd: self.gemm_config(bwd),
            grad: self.gemm_config(grad),
        }
    }

    /// The per-GEMM NZR triple this policy assumes when no per-group
    /// model applies (custom networks, the trainer's three GEMMs).
    pub fn nzr_triple(&self) -> NzrTriple {
        self.nzr
            .as_ref()
            .map(|m| m.default)
            .unwrap_or(DEFAULT_RELU_NZR)
    }

    /// Serialize to the wire form used by [`crate::api::serve`].
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("m_p", self.m_p);
        j.set("acc_exp_bits", self.acc_exp_bits);
        j.set(
            "chunk",
            self.chunk.map(Json::from).unwrap_or(Json::Null),
        );
        j.set(
            "repr",
            self.repr.map(format_to_json).unwrap_or(Json::Null),
        );
        j.set("prod", format_to_json(self.prod));
        j.set(
            "rounding",
            match self.rounding {
                Rounding::NearestEven => "nearest_even",
                Rounding::TowardZero => "toward_zero",
            },
        );
        j.set(
            "nzr",
            self.nzr.as_ref().map(nzr_to_json).unwrap_or(Json::Null),
        );
        j
    }

    /// Parse the wire form; absent or null fields fall back to
    /// [`PrecisionPolicy::paper`] defaults, type-mismatched fields are
    /// errors (never silently defaulted).
    pub fn from_json(j: &Json) -> Result<PrecisionPolicy> {
        if !matches!(j, Json::Obj(_)) {
            bail!("'policy' must be an object, got {j}");
        }
        let mut p = PrecisionPolicy::paper();
        if let Some(v) = super::opt_num(j, "m_p")? {
            p.m_p = v as u32;
        }
        if let Some(v) = super::opt_num(j, "acc_exp_bits")? {
            p.acc_exp_bits = v as u32;
        }
        if let Some(v) = super::opt_num(j, "chunk")? {
            p.chunk = Some(v as usize);
        }
        if let Some(f) = j.get("repr") {
            p.repr = match f {
                Json::Null => None,
                other => Some(format_from_json(other)?),
            };
        }
        if let Some(f) = j.get("prod") {
            p.prod = format_from_json(f)?;
        }
        if let Some(r) = j.get("rounding").and_then(Json::as_str) {
            p.rounding = match r {
                "nearest_even" => Rounding::NearestEven,
                "toward_zero" => Rounding::TowardZero,
                other => bail!("unknown rounding '{other}' (nearest_even|toward_zero)"),
            };
        }
        if let Some(m) = j.get("nzr") {
            p.nzr = match m {
                Json::Null => None,
                other => Some(nzr_from_json(other)?),
            };
        }
        p.validate()?;
        Ok(p)
    }
}

/// Builder for [`PrecisionPolicy`] with validation at [`Self::build`].
///
/// Starts from the paper defaults; every setter overrides one field.
/// `build()` runs [`PrecisionPolicy::validate`], so a zero `m_p`, a
/// zero chunk, or an out-of-range sparsity fails here instead of deep
/// inside the solver.
///
/// ```
/// use abws::api::PrecisionPolicy;
///
/// let policy = PrecisionPolicy::builder().m_p(4).chunk(64).build().unwrap();
/// assert_eq!(policy.chunk, Some(64));
/// assert!(PrecisionPolicy::builder().m_p(0).build().is_err());
/// ```
#[derive(Clone, Debug, Default)]
pub struct PrecisionPolicyBuilder {
    policy: PrecisionPolicy,
}

impl PrecisionPolicyBuilder {
    /// Representation format quantizing GEMM inputs (`None` = keep f32).
    pub fn repr(mut self, repr: Option<FpFormat>) -> Self {
        self.policy.repr = repr;
        self
    }

    /// Product-term format.
    pub fn prod(mut self, prod: FpFormat) -> Self {
        self.policy.prod = prod;
        self
    }

    /// Accumulator exponent bits.
    pub fn acc_exp_bits(mut self, bits: u32) -> Self {
        self.policy.acc_exp_bits = bits;
        self
    }

    /// Product mantissa width for the VRR analysis.
    pub fn m_p(mut self, m_p: u32) -> Self {
        self.policy.m_p = m_p;
        self
    }

    /// Two-level accumulation with this chunk size.
    pub fn chunk(mut self, chunk: usize) -> Self {
        self.policy.chunk = Some(chunk);
        self
    }

    /// Chunking from an `Option` (CLI flags that may be absent).
    pub fn maybe_chunk(mut self, chunk: Option<usize>) -> Self {
        self.policy.chunk = chunk;
        self
    }

    /// Sequential (unchunked) accumulation.
    pub fn sequential(mut self) -> Self {
        self.policy.chunk = None;
        self
    }

    /// Mantissa rounding mode.
    pub fn rounding(mut self, rounding: Rounding) -> Self {
        self.policy.rounding = rounding;
        self
    }

    /// Sparsity model.
    pub fn nzr(mut self, nzr: NzrModel) -> Self {
        self.policy.nzr = Some(nzr);
        self
    }

    /// Validate and return the policy.
    pub fn build(self) -> Result<PrecisionPolicy> {
        self.policy.validate()?;
        Ok(self.policy)
    }
}

/// Default ReLU-network sparsity when neither the policy nor the network
/// pins a model: dense FWD operands, half-zero BWD/GRAD operands.
pub const DEFAULT_RELU_NZR: NzrTriple = NzrTriple {
    fwd: 1.0,
    bwd: 0.5,
    grad: 0.5,
};

/// Full-precision control plan (the paper's ideal-accumulation baseline).
pub fn baseline_plan() -> PrecisionPlan {
    PrecisionPlan::baseline()
}

/// (1,5,2) representations with ideal accumulation — the fair baseline of
/// the paper's Fig. 6 (representation effects excluded).
pub fn fp8_ideal_acc_plan() -> PrecisionPlan {
    PrecisionPlan::fp8_ideal_acc()
}

fn format_to_json(f: FpFormat) -> Json {
    let mut j = Json::obj();
    j.set("exp_bits", f.exp_bits);
    j.set("man_bits", f.man_bits);
    j
}

fn format_from_json(j: &Json) -> Result<FpFormat> {
    let exp = j.get("exp_bits").and_then(Json::as_f64);
    let man = j.get("man_bits").and_then(Json::as_f64);
    match (exp, man) {
        (Some(e), Some(m)) => Ok(FpFormat::new(e as u32, m as u32)),
        _ => bail!("format must be {{\"exp_bits\":E,\"man_bits\":M}}"),
    }
}

fn triple_to_json(t: &NzrTriple) -> Json {
    let mut j = Json::obj();
    j.set("fwd", t.fwd);
    j.set("bwd", t.bwd);
    j.set("grad", t.grad);
    j
}

fn triple_from_json(j: &Json) -> Result<NzrTriple> {
    let g = |k: &str| -> Result<f64> {
        match j.get(k).and_then(Json::as_f64) {
            Some(v) => Ok(v),
            None => bail!("nzr triple missing '{k}'"),
        }
    };
    Ok(NzrTriple {
        fwd: g("fwd")?,
        bwd: g("bwd")?,
        grad: g("grad")?,
    })
}

fn nzr_to_json(m: &NzrModel) -> Json {
    let mut j = Json::obj();
    j.set("default", triple_to_json(&m.default));
    let mut groups = Json::obj();
    for (g, t) in &m.per_group {
        groups.set(g, triple_to_json(t));
    }
    j.set("per_group", groups);
    j
}

fn nzr_from_json(j: &Json) -> Result<NzrModel> {
    let default = match j.get("default") {
        Some(t) => triple_from_json(t)?,
        None => bail!("nzr model missing 'default' triple"),
    };
    let mut model = NzrModel {
        default,
        per_group: Default::default(),
    };
    if let Some(Json::Obj(groups)) = j.get("per_group") {
        for (g, t) in groups {
            model.per_group.insert(g.clone(), triple_from_json(t)?);
        }
    }
    Ok(model)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_policy_matches_legacy_configs() {
        let p = PrecisionPolicy::paper().with_chunk(Some(64));
        let legacy = GemmConfig::paper(8, Some(64));
        let derived = p.gemm_config(8);
        assert_eq!(derived.repr, legacy.repr);
        assert_eq!(derived.prod, legacy.prod);
        assert_eq!(derived.acc, legacy.acc);
        assert_eq!(derived.chunk, legacy.chunk);
        assert_eq!(derived.mode, legacy.mode);

        let spec = p.accum_spec(4096, 0.5);
        assert_eq!(spec.n, 4096);
        assert_eq!(spec.m_p, 5);
        assert_eq!(spec.chunk, Some(64));
    }

    #[test]
    fn plan_builders_match_legacy() {
        let p = PrecisionPolicy::paper();
        let uni = p.plan_uniform(12);
        let legacy = PrecisionPlan::uniform(12, None);
        assert_eq!(uni.fwd.acc, legacy.fwd.acc);
        let per = p.plan_per_gemm(9, 8, 15);
        assert_eq!(per.grad.acc.man_bits, 15);
        assert_eq!(per.fwd.acc.man_bits, 9);
    }

    #[test]
    fn validate_rejects_nonsense() {
        assert!(PrecisionPolicy::paper().validate().is_ok());
        assert!(PrecisionPolicy::paper().with_m_p(0).validate().is_err());
        assert!(PrecisionPolicy::paper()
            .with_chunk(Some(0))
            .validate()
            .is_err());
        assert!(PrecisionPolicy::paper()
            .with_nzr(NzrModel::uniform(1.0, 0.5, 1.5))
            .validate()
            .is_err());
    }

    #[test]
    fn builder_validates_at_build() {
        let p = PrecisionPolicy::builder()
            .m_p(7)
            .chunk(128)
            .rounding(Rounding::TowardZero)
            .build()
            .unwrap();
        assert_eq!(p.m_p, 7);
        assert_eq!(p.chunk, Some(128));
        assert_eq!(p.rounding, Rounding::TowardZero);
        // Untouched fields keep the paper defaults.
        assert_eq!(p.acc_exp_bits, 6);
        assert_eq!(p.prod, FpFormat::PROD_FP8);

        assert!(PrecisionPolicy::builder().m_p(0).build().is_err());
        assert!(PrecisionPolicy::builder().m_p(53).build().is_err());
        assert!(PrecisionPolicy::builder().chunk(0).build().is_err());
        assert!(PrecisionPolicy::builder().acc_exp_bits(1).build().is_err());
        assert!(PrecisionPolicy::builder()
            .nzr(NzrModel::uniform(1.0, 0.5, 1.5))
            .build()
            .is_err());
        let seq = PrecisionPolicy::builder().chunk(64).sequential().build().unwrap();
        assert!(seq.chunk.is_none());
        let opt = PrecisionPolicy::builder().maybe_chunk(Some(32)).build().unwrap();
        assert_eq!(opt.chunk, Some(32));
    }

    #[test]
    fn checked_accum_spec_rejects_degenerate_lengths() {
        let p = PrecisionPolicy::paper().with_chunk(Some(64));
        assert!(p.checked_accum_spec(0, 1.0).is_err());
        assert!(p.checked_accum_spec(32, 1.0).is_err()); // chunk 64 > n 32
        let spec = p.checked_accum_spec(4096, 0.5).unwrap();
        assert_eq!(spec, p.accum_spec(4096, 0.5));
        // Sequential policies only reject n == 0.
        assert!(PrecisionPolicy::paper().checked_accum_spec(1, 1.0).is_ok());
    }

    #[test]
    fn json_roundtrip() {
        let p = PrecisionPolicy::paper()
            .with_chunk(Some(128))
            .with_m_p(7)
            .with_nzr(NzrModel::uniform(1.0, 0.4, 0.1).with_group("Conv 1", 0.9, 0.3, 0.05));
        let text = p.to_json().to_string();
        let back = PrecisionPolicy::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string(), text);
        assert_eq!(back.m_p, 7);
        assert_eq!(back.chunk, Some(128));
        assert_eq!(back.nzr.unwrap().lookup("Conv 1", crate::nets::Gemm::Grad), 0.05);
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let p = PrecisionPolicy::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(p.m_p, 5);
        assert_eq!(p.chunk, None);
        assert!(p.nzr.is_none());
        assert_eq!(p.prod, FpFormat::PROD_FP8);
    }
}
