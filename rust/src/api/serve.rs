//! The batch front door: newline-delimited JSON requests in, one JSON
//! report per line out.
//!
//! Each input line is a JSON object whose `"type"` selects the handler —
//! `"advisor"` (the default when omitted) or `"train"`. A malformed or
//! failing request produces an `{"error": "..."}` line *in its position*
//! and the stream keeps going, so a batch client can zip requests to
//! responses by line number. The output is flushed after every line, so
//! a downstream pipe consumer sees each response as soon as it exists
//! rather than at buffer boundaries. All solving shares the process-wide
//! [`crate::api::cache`], so a sweep of similar requests gets the
//! memoized fast path after the first.
//!
//! ## Telemetry
//!
//! When [`crate::telemetry`] is enabled (the default), every request
//! records into `abws_serve_latency_ns`, bumps
//! `abws_serve_requests_total{type=...}` (types `advisor`, `train`,
//! `unknown`, `invalid`), counts failures in `abws_serve_errors_total`,
//! and tracks in-flight work in the `abws_serve_queue_depth` gauge.

use std::io::{BufRead, Write};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use super::advisor::AdvisorRequest;
use super::train::TrainRequest;
use crate::telemetry::{self, labeled, Counter, Gauge, Histogram, Timer};
use crate::util::json::Json;

/// Counters for one [`serve`] session.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Non-empty request lines seen.
    pub requests: usize,
    /// Requests answered with an `{"error": ...}` line.
    pub errors: usize,
}

/// Request-type labels used by `abws_serve_requests_total{type=...}`.
const REQUEST_TYPES: [&str; 4] = ["advisor", "train", "unknown", "invalid"];

/// Handle one request line, returning the type label (for metrics) and
/// the report JSON.
fn handle_request_labeled(line: &str) -> (&'static str, Result<Json>) {
    let j = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return ("invalid", Err(anyhow!("bad request JSON: {e}"))),
    };
    if !matches!(j, Json::Obj(_)) {
        return ("invalid", Err(anyhow!("request must be a JSON object")));
    }
    let ty = match j.get("type") {
        None => "advisor",
        Some(Json::Str(s)) => s.as_str(),
        Some(other) => {
            return (
                "invalid",
                Err(anyhow!("'type' must be a string, got {other}")),
            )
        }
    };
    match ty {
        "advisor" => (
            "advisor",
            (|| Ok(AdvisorRequest::from_json(&j)?.run()?.to_json()))(),
        ),
        "train" => (
            "train",
            (|| Ok(TrainRequest::from_json(&j)?.resolve()?.run().to_json()))(),
        ),
        other => (
            "unknown",
            Err(anyhow!("unknown request type '{other}' (advisor|train)")),
        ),
    }
}

/// Handle one request line, returning the report JSON.
pub fn handle_request(line: &str) -> Result<Json> {
    handle_request_labeled(line).1
}

/// Metric handles for one serve session, resolved once up front.
struct ServeTelemetry {
    latency: Arc<Histogram>,
    errors: Arc<Counter>,
    queue_depth: Arc<Gauge>,
    requests: [(&'static str, Arc<Counter>); 4],
}

impl ServeTelemetry {
    fn new() -> ServeTelemetry {
        ServeTelemetry {
            latency: telemetry::histogram("abws_serve_latency_ns"),
            errors: telemetry::counter("abws_serve_errors_total"),
            queue_depth: telemetry::gauge("abws_serve_queue_depth"),
            requests: REQUEST_TYPES.map(|ty| {
                let name = labeled("abws_serve_requests_total", &[("type", ty)]);
                (ty, telemetry::counter(&name))
            }),
        }
    }

    fn count_request(&self, ty: &str) {
        if let Some((_, c)) = self.requests.iter().find(|(t, _)| *t == ty) {
            c.inc();
        }
    }
}

/// Serve newline-delimited JSON requests from `input` to `out` until EOF.
/// Blank lines are skipped; per-request failures become error lines, not
/// stream failures. Every response line (including error lines) is
/// flushed before the next request is read.
pub fn serve<R: BufRead, W: Write>(input: R, mut out: W) -> Result<ServeStats> {
    let tel = telemetry::enabled().then(ServeTelemetry::new);
    let mut stats = ServeStats::default();
    for line in input.lines() {
        let line = line.context("reading request line")?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        stats.requests += 1;
        if let Some(t) = &tel {
            t.queue_depth.inc();
        }
        let timer = tel.as_ref().map(|_| Timer::start());
        let (ty, result) = handle_request_labeled(trimmed);
        let failed = result.is_err();
        let response = match result {
            Ok(report) => report,
            Err(e) => {
                stats.errors += 1;
                let mut o = Json::obj();
                o.set("error", format!("{e:#}"));
                o
            }
        };
        if let Some(t) = &tel {
            if let Some(timer) = &timer {
                t.latency.record(timer.elapsed_ns());
            }
            t.count_request(ty);
            if failed {
                t.errors.inc();
            }
            t.queue_depth.dec();
        }
        writeln!(out, "{response}").context("writing response line")?;
        out.flush().context("flushing response line")?;
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advisor_line_answers() {
        let out = handle_request(r#"{"type":"advisor","network":"resnet32"}"#).unwrap();
        assert_eq!(out.get("type").unwrap().as_str(), Some("advisor_report"));
        assert!(!out.get("layers").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn default_type_is_advisor() {
        let out = handle_request(r#"{"network":"alexnet"}"#).unwrap();
        assert_eq!(out.get("type").unwrap().as_str(), Some("advisor_report"));
    }

    #[test]
    fn errors_are_lines_not_failures() {
        let input = "{\"network\":\"resnet32\"}\nnot json\n\n{\"network\":\"resnet18\"}\n";
        let mut out = Vec::new();
        let stats = serve(input.as_bytes(), &mut out).unwrap();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.errors, 1);
        let lines: Vec<&str> = std::str::from_utf8(&out).unwrap().lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[1].contains("error"));
        assert!(Json::parse(lines[0]).unwrap().get("layers").is_some());
        assert!(Json::parse(lines[2]).unwrap().get("layers").is_some());
    }

    #[test]
    fn unknown_type_is_an_error_line() {
        let mut out = Vec::new();
        let stats = serve("{\"type\":\"frobnicate\"}\n".as_bytes(), &mut out).unwrap();
        assert_eq!(stats.errors, 1);
        assert!(String::from_utf8(out).unwrap().contains("unknown request type"));
    }

    #[test]
    fn request_type_labels_cover_dispatch() {
        assert_eq!(handle_request_labeled("not json").0, "invalid");
        assert_eq!(handle_request_labeled("[1,2]").0, "invalid");
        assert_eq!(handle_request_labeled(r#"{"type":3}"#).0, "invalid");
        assert_eq!(handle_request_labeled(r#"{"type":"nope"}"#).0, "unknown");
        assert_eq!(
            handle_request_labeled(r#"{"network":"resnet32"}"#).0,
            "advisor"
        );
        assert_eq!(handle_request_labeled(r#"{"type":"train"}"#).0, "train");
    }

    /// Satellite requirement: each response line reaches the consumer as
    /// soon as it is written (flush after every line).
    #[test]
    fn output_is_flushed_per_line() {
        struct CountingWriter {
            flushes: usize,
            buf: Vec<u8>,
        }
        impl Write for CountingWriter {
            fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
                self.buf.extend_from_slice(data);
                Ok(data.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                self.flushes += 1;
                Ok(())
            }
        }
        let input = "{\"network\":\"resnet32\"}\nbad\n{\"network\":\"alexnet\"}\n";
        let mut w = CountingWriter {
            flushes: 0,
            buf: Vec::new(),
        };
        let stats = serve(input.as_bytes(), &mut w).unwrap();
        assert_eq!(stats.requests, 3);
        // One flush per response line, error lines included.
        assert!(w.flushes >= 3, "flushes={}", w.flushes);
        assert_eq!(String::from_utf8(w.buf).unwrap().lines().count(), 3);
    }
}
