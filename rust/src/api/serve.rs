//! The batch front door: newline-delimited JSON requests in, one JSON
//! report per line out.
//!
//! Each input line is a JSON object whose `"type"` selects the handler —
//! `"advisor"` (the default when omitted) or `"train"`. A malformed or
//! failing request produces an `{"error": "..."}` line *in its position*
//! and the stream keeps going, so a batch client can zip requests to
//! responses by line number. All solving shares the process-wide
//! [`crate::api::cache`], so a sweep of similar requests gets the
//! memoized fast path after the first.

use std::io::{BufRead, Write};

use anyhow::{anyhow, bail, Context, Result};

use super::advisor::AdvisorRequest;
use super::train::TrainRequest;
use crate::util::json::Json;

/// Counters for one [`serve`] session.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Non-empty request lines seen.
    pub requests: usize,
    /// Requests answered with an `{"error": ...}` line.
    pub errors: usize,
}

/// Handle one request line, returning the report JSON.
pub fn handle_request(line: &str) -> Result<Json> {
    let j = Json::parse(line).map_err(|e| anyhow!("bad request JSON: {e}"))?;
    if !matches!(j, Json::Obj(_)) {
        bail!("request must be a JSON object");
    }
    let ty = match j.get("type") {
        None => "advisor",
        Some(Json::Str(s)) => s.as_str(),
        Some(other) => bail!("'type' must be a string, got {other}"),
    };
    match ty {
        "advisor" => Ok(AdvisorRequest::from_json(&j)?.run()?.to_json()),
        "train" => Ok(TrainRequest::from_json(&j)?.resolve()?.run().to_json()),
        other => bail!("unknown request type '{other}' (advisor|train)"),
    }
}

/// Serve newline-delimited JSON requests from `input` to `out` until EOF.
/// Blank lines are skipped; per-request failures become error lines, not
/// stream failures.
pub fn serve<R: BufRead, W: Write>(input: R, mut out: W) -> Result<ServeStats> {
    let mut stats = ServeStats::default();
    for line in input.lines() {
        let line = line.context("reading request line")?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        stats.requests += 1;
        let response = match handle_request(trimmed) {
            Ok(report) => report,
            Err(e) => {
                stats.errors += 1;
                let mut o = Json::obj();
                o.set("error", format!("{e:#}"));
                o
            }
        };
        writeln!(out, "{response}").context("writing response line")?;
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advisor_line_answers() {
        let out = handle_request(r#"{"type":"advisor","network":"resnet32"}"#).unwrap();
        assert_eq!(out.get("type").unwrap().as_str(), Some("advisor_report"));
        assert!(!out.get("layers").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn default_type_is_advisor() {
        let out = handle_request(r#"{"network":"alexnet"}"#).unwrap();
        assert_eq!(out.get("type").unwrap().as_str(), Some("advisor_report"));
    }

    #[test]
    fn errors_are_lines_not_failures() {
        let input = "{\"network\":\"resnet32\"}\nnot json\n\n{\"network\":\"resnet18\"}\n";
        let mut out = Vec::new();
        let stats = serve(input.as_bytes(), &mut out).unwrap();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.errors, 1);
        let lines: Vec<&str> = std::str::from_utf8(&out).unwrap().lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[1].contains("error"));
        assert!(Json::parse(lines[0]).unwrap().get("layers").is_some());
        assert!(Json::parse(lines[2]).unwrap().get("layers").is_some());
    }

    #[test]
    fn unknown_type_is_an_error_line() {
        let mut out = Vec::new();
        let stats = serve("{\"type\":\"frobnicate\"}\n".as_bytes(), &mut out).unwrap();
        assert_eq!(stats.errors, 1);
        assert!(String::from_utf8(out).unwrap().contains("unknown request type"));
    }
}
