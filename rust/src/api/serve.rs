//! The batch front door: newline-delimited JSON requests in, one JSON
//! report per line out — sequentially or through a pooled, fault-isolated
//! pipeline.
//!
//! ## The v1 envelope
//!
//! Each input line is a JSON object whose `"type"` selects the handler —
//! `"advisor"` (the default when omitted), `"train"`, `"check"`, or
//! `"test"` (empirical Monte-Carlo VRR sweeps on the shared worker
//! pool). Two
//! optional envelope fields ride along: `"v"` (protocol version; missing
//! means v1, anything other than 1 is a structured error) and `"id"`
//! (any JSON value, echoed back verbatim in the matching reply or error
//! line so concurrent clients can correlate without relying on line
//! order). A malformed or failing request produces an
//! `{"error": {"kind": ..., "message": ...}}` line *in its position*
//! (plus a deprecated top-level `"message"` string — see
//! `docs/serve.md`) and the stream keeps going, so a batch client can
//! zip requests to responses by line number. The output is flushed after
//! every line. All solving shares the process-wide
//! [`crate::api::cache`], so a sweep of similar requests gets the
//! memoized fast path after the first.
//!
//! ## The concurrent pipeline
//!
//! [`serve_with`] at `workers >= 2` runs a reader thread feeding a
//! bounded admission gate (`queue_depth` waiting requests beyond the
//! workers — the reader blocks when the batch runs ahead, which is what
//! propagates backpressure up the OS pipe), a pool of workers executing
//! requests, and an in-order reassembly stage on the calling thread that
//! buffers out-of-order completions and writes replies strictly in input
//! order. Output is **byte-identical** to sequential mode. Every request
//! runs under [`std::panic::catch_unwind`], so a panicking handler
//! yields an error line of kind `panic` in its slot instead of killing
//! the batch, and an optional per-request deadline (`timeout_ms`)
//! degrades slow requests to kind `timeout` (the `train` step loop
//! checks it cooperatively between steps).
//!
//! ## Telemetry
//!
//! When [`crate::telemetry`] is enabled (the default), every request
//! records into `abws_serve_latency_ns`, bumps
//! `abws_serve_requests_total{type=...}` (types `advisor`, `train`,
//! `check`, `test`, `unknown`, `invalid`), counts failures in
//! `abws_serve_errors_total`, and tracks in-flight work in the
//! `abws_serve_queue_depth` gauge. The pipeline additionally records
//! per-request time-in-queue into `abws_serve_queue_wait_ns` and each
//! worker's busy percentage into `abws_serve_worker_utilization_pct`.

use std::collections::BTreeMap;
use std::io::{BufRead, Write};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::advisor::AdvisorRequest;
use super::check::CheckRequest;
use super::error::{ApiError, ErrorKind};
use super::mctest::TestRequest;
use super::train::TrainRequest;
use crate::telemetry::{self, labeled, trace, Counter, Gauge, Histogram, Timer};
use crate::util::json::Json;

/// Counters for one [`serve`] session.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Non-empty request lines seen.
    pub requests: usize,
    /// Requests answered with an `{"error": ...}` line (any kind).
    pub errors: usize,
    /// The subset of `errors` with kind `timeout`.
    pub timeouts: usize,
    /// The subset of `errors` with kind `panic`.
    pub panics: usize,
}

impl ServeStats {
    fn tally(&mut self, reply: &Reply) {
        self.requests += 1;
        if reply.failed {
            self.errors += 1;
        }
        if reply.timed_out {
            self.timeouts += 1;
        }
        if reply.panicked {
            self.panics += 1;
        }
    }
}

/// Knobs for [`serve_with`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeOptions {
    /// Worker threads; `0` means one per available core
    /// ([`default_workers`]), `1` is the sequential path.
    pub workers: usize,
    /// Requests admitted beyond the workers (read but not yet picked
    /// up). The reader blocks once `queue_depth + workers` requests are
    /// in flight.
    pub queue_depth: usize,
    /// Per-request deadline in milliseconds (`None` = no deadline).
    pub timeout_ms: Option<u64>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            workers: 1,
            queue_depth: 128,
            timeout_ms: None,
        }
    }
}

/// The default worker count for `workers: 0`: one per available core.
pub fn default_workers() -> usize {
    crate::coordinator::sweep::default_threads()
}

/// Request-type labels used by `abws_serve_requests_total{type=...}`.
/// Hidden test-only request types (`__panic`, `__sleep`) collapse into
/// the `test` label to keep its cardinality bounded.
const REQUEST_TYPES: [&str; 6] = ["advisor", "train", "check", "test", "unknown", "invalid"];

/// A parsed v1 request envelope: the body, the correlation id to echo,
/// and the dispatch type.
struct Envelope {
    body: Json,
    id: Option<Json>,
    ty: String,
}

/// Parse a line into an [`Envelope`]. On failure, the error comes back
/// with whatever `"id"` could still be recovered (JSON that parsed but
/// had a bad version still correlates).
fn parse_envelope(line: &str) -> Result<Envelope, (ApiError, Option<Json>)> {
    let j = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return Err((ApiError::parse(format!("bad request JSON: {e}")), None)),
    };
    if !matches!(j, Json::Obj(_)) {
        return Err((ApiError::invalid("request must be a JSON object"), None));
    }
    let id = j.get("id").cloned();
    match j.get("v") {
        None | Some(Json::Null) => {}
        Some(Json::Num(v)) if *v == 1.0 => {}
        Some(other) => {
            return Err((
                ApiError::invalid(format!(
                    "unsupported envelope version {other} (this server speaks v1)"
                )),
                id,
            ))
        }
    }
    let ty = match j.get("type") {
        None => "advisor".to_string(),
        Some(Json::Str(s)) => s.clone(),
        Some(other) => {
            return Err((
                ApiError::invalid(format!("'type' must be a string, got {other}")),
                id,
            ))
        }
    };
    Ok(Envelope { body: j, id, ty })
}

/// Metric label for a request type.
fn label_for(ty: &str) -> &'static str {
    match ty {
        "advisor" => "advisor",
        "train" => "train",
        "check" => "check",
        "test" | "__panic" | "__sleep" => "test",
        _ => "unknown",
    }
}

/// Map a request-shaped `anyhow` failure to kind `invalid`.
fn invalid(e: anyhow::Error) -> ApiError {
    ApiError::invalid(format!("{e:#}"))
}

fn run_advisor(j: &Json) -> Result<Json, ApiError> {
    let req = AdvisorRequest::from_json(j).map_err(invalid)?;
    let report = req.run().map_err(invalid)?;
    Ok(report.to_json())
}

fn run_train(j: &Json, deadline: Option<Instant>) -> Result<Json, ApiError> {
    let req = TrainRequest::from_json(j).map_err(invalid)?;
    let resolved = req.resolve().map_err(invalid)?;
    let report = resolved.run_with_deadline(deadline)?;
    Ok(report.to_json())
}

fn run_check(j: &Json) -> Result<Json, ApiError> {
    let req = CheckRequest::from_json(j).map_err(invalid)?;
    let report = req.run().map_err(invalid)?;
    Ok(report.to_json())
}

fn run_test(j: &Json) -> Result<Json, ApiError> {
    let req = TestRequest::from_json(j).map_err(invalid)?;
    // Structured engine rejections (trials < 2, n == 0, …) surface here
    // as the unified `{"error":{...}}` shape, kind `invalid`.
    let report = req.run().map_err(invalid)?;
    Ok(report.to_json())
}

/// Hidden test-only handler: sleep for `"ms"` in 1 ms cooperative
/// slices, honoring the deadline. Exists so integration tests can force
/// out-of-order completion and timeouts deterministically.
fn run_sleep(j: &Json, deadline: Option<Instant>) -> Result<Json, ApiError> {
    let ms = super::opt_num(j, "ms").map_err(invalid)?.unwrap_or(10.0);
    if !ms.is_finite() || ms < 0.0 {
        return Err(ApiError::invalid(format!("'ms' must be >= 0, got {ms}")));
    }
    let ms = ms as u64;
    let target = Instant::now() + Duration::from_millis(ms);
    loop {
        if let Some(d) = deadline {
            if Instant::now() >= d {
                return Err(ApiError::timeout(format!(
                    "__sleep request exceeded its deadline before {ms}ms elapsed"
                )));
            }
        }
        let now = Instant::now();
        if now >= target {
            break;
        }
        std::thread::sleep((target - now).min(Duration::from_millis(1)));
    }
    let mut report = Json::obj();
    report.set("type", "__sleep_report");
    report.set("ms", ms);
    Ok(report)
}

/// Route an envelope to its handler.
fn dispatch(env: &Envelope, deadline: Option<Instant>) -> Result<Json, ApiError> {
    match env.ty.as_str() {
        "advisor" => run_advisor(&env.body),
        "train" => run_train(&env.body, deadline),
        "check" => run_check(&env.body),
        "test" => run_test(&env.body),
        // Hidden test-only handlers (integration tests can't see
        // cfg(test) items, so these are always compiled but
        // undocumented).
        "__panic" => panic!("injected panic from the hidden '__panic' test request"),
        "__sleep" => run_sleep(&env.body, deadline),
        other => Err(ApiError::invalid(format!(
            "unknown request type '{other}' (advisor|train|check|test)"
        ))),
    }
}

/// Handle one request line, returning the report JSON. Legacy
/// single-request entry point; the envelope's `id` is echoed into the
/// report, and failures come back as `anyhow` errors carrying the
/// [`ApiError`] message.
pub fn handle_request(line: &str) -> Result<Json> {
    let env = parse_envelope(line).map_err(|(e, _)| anyhow::Error::from(e))?;
    let mut report = dispatch(&env, None).map_err(anyhow::Error::from)?;
    if let Some(id) = &env.id {
        report.set("id", id.clone());
    }
    Ok(report)
}

/// One fully-rendered response line with the flags the stats/telemetry
/// tally needs.
#[derive(Clone, Debug)]
struct Reply {
    ty: &'static str,
    line: String,
    failed: bool,
    timed_out: bool,
    panicked: bool,
}

fn error_reply(ty: &'static str, err: ApiError, id: Option<Json>) -> Reply {
    let mut o = Json::obj();
    o.set("error", err.to_json());
    // Deprecated: the pre-v1 bare-string error field, kept for one
    // release (see docs/serve.md).
    o.set("message", err.message.as_str());
    if let Some(id) = id {
        o.set("id", id);
    }
    Reply {
        ty,
        line: o.to_string(),
        failed: true,
        timed_out: err.kind == ErrorKind::Timeout,
        panicked: err.kind == ErrorKind::Panic,
    }
}

/// Best-effort text of a panic payload (`&str` and `String` payloads
/// cover `panic!`; anything else is opaque).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// Execute one trimmed request line end to end: envelope, deadline,
/// panic isolation, id echo. Both the sequential and the concurrent
/// paths answer through this one function — that is what makes their
/// output byte-identical.
fn handle_line(line: &str, timeout_ms: Option<u64>) -> Reply {
    let env = match parse_envelope(line) {
        Ok(env) => env,
        Err((err, id)) => return error_reply("invalid", err, id),
    };
    let ty = label_for(&env.ty);
    // Root span of this request's trace tree: everything dispatch
    // touches (trainer steps, GEMM panels, pool regions, MC trials,
    // solver/cache calls) hangs below it. Spans opened inside a
    // panicking handler unwind-record before `catch_unwind` returns, so
    // a panicked request still ships a complete subtree.
    let _rspan = if trace::enabled() {
        let s = trace::TraceSpan::enter("serve.request").attr("type", ty);
        match &env.id {
            Some(id) => s.attr("id", id.to_string()),
            None => s,
        }
    } else {
        trace::TraceSpan::noop()
    };
    let deadline = timeout_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
    match catch_unwind(AssertUnwindSafe(|| dispatch(&env, deadline))) {
        Ok(Ok(mut report)) => {
            if let Some(id) = &env.id {
                report.set("id", id.clone());
            }
            Reply {
                ty,
                line: report.to_string(),
                failed: false,
                timed_out: false,
                panicked: false,
            }
        }
        Ok(Err(err)) => error_reply(ty, err, env.id),
        Err(payload) => error_reply(
            ty,
            ApiError::panic(format!(
                "request handler panicked: {}",
                panic_message(payload.as_ref())
            )),
            env.id,
        ),
    }
}

/// Metric handles for one serve session, resolved once up front.
struct ServeTelemetry {
    latency: Arc<Histogram>,
    queue_wait: Arc<Histogram>,
    worker_utilization: Arc<Histogram>,
    errors: Arc<Counter>,
    queue_depth: Arc<Gauge>,
    requests: [(&'static str, Arc<Counter>); REQUEST_TYPES.len()],
}

impl ServeTelemetry {
    fn new() -> ServeTelemetry {
        ServeTelemetry {
            latency: telemetry::histogram("abws_serve_latency_ns"),
            queue_wait: telemetry::histogram("abws_serve_queue_wait_ns"),
            worker_utilization: telemetry::histogram("abws_serve_worker_utilization_pct"),
            errors: telemetry::counter("abws_serve_errors_total"),
            queue_depth: telemetry::gauge("abws_serve_queue_depth"),
            requests: REQUEST_TYPES.map(|ty| {
                let name = labeled("abws_serve_requests_total", &[("type", ty)]);
                (ty, telemetry::counter(&name))
            }),
        }
    }

    fn count_request(&self, ty: &str) {
        if let Some((_, c)) = self.requests.iter().find(|(t, _)| *t == ty) {
            c.inc();
        }
    }

    /// Per-reply bookkeeping shared by both paths (latency, type count,
    /// error count).
    fn record_reply(&self, reply: &Reply, elapsed_ns: u64) {
        self.latency.record(elapsed_ns);
        self.count_request(reply.ty);
        if reply.failed {
            self.errors.inc();
        }
    }
}

/// Serve newline-delimited JSON requests from `input` to `out` until
/// EOF with the default options (sequential, no deadline). Blank lines
/// are skipped; per-request failures become error lines, not stream
/// failures. Every response line (including error lines) is flushed
/// before the next is written.
pub fn serve<R: BufRead + Send, W: Write>(input: R, out: W) -> Result<ServeStats> {
    serve_with(input, out, &ServeOptions::default())
}

/// [`serve`] with explicit [`ServeOptions`]. `workers >= 2` runs the
/// concurrent pipeline; output stays byte-identical to sequential mode.
pub fn serve_with<R: BufRead + Send, W: Write>(
    input: R,
    out: W,
    opts: &ServeOptions,
) -> Result<ServeStats> {
    let workers = if opts.workers == 0 {
        default_workers()
    } else {
        opts.workers
    };
    let tel = telemetry::enabled().then(ServeTelemetry::new);
    if workers <= 1 {
        serve_sequential(input, out, opts.timeout_ms, tel.as_ref())
    } else {
        serve_concurrent(input, out, workers, opts, tel.as_ref())
    }
}

fn serve_sequential<R: BufRead, W: Write>(
    input: R,
    mut out: W,
    timeout_ms: Option<u64>,
    tel: Option<&ServeTelemetry>,
) -> Result<ServeStats> {
    let mut stats = ServeStats::default();
    for line in input.lines() {
        let line = line.context("reading request line")?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        if let Some(t) = tel {
            t.queue_depth.inc();
        }
        let timer = Timer::start();
        let reply = handle_line(trimmed, timeout_ms);
        if let Some(t) = tel {
            t.record_reply(&reply, timer.elapsed_ns());
            t.queue_depth.dec();
        }
        stats.tally(&reply);
        if reply.timed_out || reply.panicked {
            // Flight-recorder dump: the failed request's span tree (plus
            // recent context) lands at the configured `--trace-out` path.
            trace::dump_now();
        }
        writeln!(out, "{}", reply.line).context("writing response line")?;
        out.flush().context("flushing response line")?;
    }
    Ok(stats)
}

/// One admitted request traveling reader → worker.
struct Job {
    seq: u64,
    line: String,
    enqueued: Instant,
}

/// Counting semaphore bounding total in-flight requests (read but not
/// yet written). Admission is FIFO-ish via the condvar, and — crucially
/// — the *reader* is the only acquirer, so the request holding the next
/// output slot is always already admitted: reassembly can never
/// deadlock waiting for a request the gate is holding back.
struct Gate {
    state: Mutex<GateState>,
    cv: Condvar,
}

struct GateState {
    available: usize,
    closed: bool,
}

impl Gate {
    fn new(capacity: usize) -> Gate {
        Gate {
            state: Mutex::new(GateState {
                available: capacity,
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Block until a slot is free. Returns immediately (without taking a
    /// slot) once the gate is closed.
    fn acquire(&self) {
        let mut st = self.state.lock().unwrap();
        while st.available == 0 && !st.closed {
            st = self.cv.wait(st).unwrap();
        }
        if !st.closed {
            st.available -= 1;
        }
    }

    fn release(&self) {
        let mut st = self.state.lock().unwrap();
        st.available += 1;
        self.cv.notify_one();
    }

    /// Unblock every waiter and make further acquires no-ops (shutdown
    /// after a write error).
    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.cv.notify_all();
    }
}

/// The worker loop: shared-dequeue from the job channel, execute, send
/// `(seq, reply)` to reassembly. Records queue wait per job and its own
/// busy percentage at exit.
fn worker_loop(
    jobs: &Mutex<mpsc::Receiver<Job>>,
    results: mpsc::Sender<(u64, Reply)>,
    timeout_ms: Option<u64>,
    tel: Option<&ServeTelemetry>,
) {
    let started = Instant::now();
    let mut busy_ns: u64 = 0;
    // Not `while let`: on edition 2021 a while-let scrutinee temporary
    // lives for the whole loop body, which would hold the dequeue lock
    // across request execution and serialize the pool.
    #[allow(clippy::while_let_loop)]
    loop {
        // The lock is held only for the blocking dequeue (released when
        // this statement's temporary guard drops); execution is parallel.
        let job = match jobs.lock().unwrap().recv() {
            Ok(job) => job,
            Err(_) => break, // reader done and queue drained
        };
        if let Some(t) = tel {
            t.queue_wait.record_duration(job.enqueued.elapsed());
        }
        let timer = Timer::start();
        let reply = handle_line(&job.line, timeout_ms);
        let elapsed = timer.elapsed_ns();
        busy_ns = busy_ns.saturating_add(elapsed);
        if let Some(t) = tel {
            t.record_reply(&reply, elapsed);
        }
        if results.send((job.seq, reply)).is_err() {
            break; // reassembly gone (write error shutdown)
        }
    }
    if let Some(t) = tel {
        let lifetime_ns = started.elapsed().as_nanos().max(1);
        let pct = (busy_ns as u128 * 100 / lifetime_ns).min(100) as u64;
        t.worker_utilization.record(pct);
    }
}

fn serve_concurrent<R: BufRead + Send, W: Write>(
    input: R,
    mut out: W,
    workers: usize,
    opts: &ServeOptions,
    tel: Option<&ServeTelemetry>,
) -> Result<ServeStats> {
    let timeout_ms = opts.timeout_ms;
    // Total in-flight bound: `queue_depth` waiting + one per worker.
    // This also bounds the reassembly buffer, since every buffered reply
    // still holds its gate slot until written.
    let gate = Gate::new(opts.queue_depth.max(1) + workers);
    let aborted = AtomicBool::new(false);
    let (job_tx, job_rx) = mpsc::channel::<Job>();
    let job_rx = Mutex::new(job_rx);
    let (res_tx, res_rx) = mpsc::channel::<(u64, Reply)>();

    let mut stats = ServeStats::default();
    let mut write_result: Result<()> = Ok(());

    std::thread::scope(|s| -> Result<()> {
        let gate = &gate;
        let aborted = &aborted;
        let job_rx = &job_rx;

        let reader = s.spawn(move || -> Result<()> {
            let mut seq = 0u64;
            for line in input.lines() {
                let line = line.context("reading request line")?;
                let trimmed = line.trim();
                if trimmed.is_empty() {
                    continue;
                }
                gate.acquire();
                if aborted.load(Ordering::SeqCst) {
                    break;
                }
                if let Some(t) = tel {
                    t.queue_depth.inc();
                }
                let job = Job {
                    seq,
                    line: trimmed.to_string(),
                    enqueued: Instant::now(),
                };
                seq += 1;
                if job_tx.send(job).is_err() {
                    break;
                }
            }
            Ok(())
        });

        for _ in 0..workers {
            let res_tx = res_tx.clone();
            s.spawn(move || worker_loop(job_rx, res_tx, timeout_ms, tel));
        }
        // Reassembly holds no sender; the iterator below ends when the
        // last worker exits.
        drop(res_tx);

        let mut pending: BTreeMap<u64, Reply> = BTreeMap::new();
        let mut next_seq = 0u64;
        for (seq, reply) in res_rx.iter() {
            pending.insert(seq, reply);
            // Admission is FIFO from one reader, so the reply for
            // `next_seq` is always in flight — drain every run of
            // consecutive sequence numbers as it completes.
            while let Some(reply) = pending.remove(&next_seq) {
                next_seq += 1;
                gate.release();
                if let Some(t) = tel {
                    t.queue_depth.dec();
                }
                stats.tally(&reply);
                if reply.timed_out || reply.panicked {
                    // Same failure dump as the sequential path.
                    trace::dump_now();
                }
                if write_result.is_ok() {
                    write_result = writeln!(out, "{}", reply.line)
                        .context("writing response line")
                        .and_then(|()| out.flush().context("flushing response line"));
                    if write_result.is_err() {
                        // Stop admitting; keep draining so every thread
                        // exits and the scope joins cleanly.
                        aborted.store(true, Ordering::SeqCst);
                        gate.close();
                    }
                }
            }
        }

        match reader.join() {
            Ok(r) => r,
            Err(_) => anyhow::bail!("serve reader thread panicked"),
        }
    })?;
    write_result?;
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advisor_line_answers() {
        let out = handle_request(r#"{"type":"advisor","network":"resnet32"}"#).unwrap();
        assert_eq!(out.get("type").unwrap().as_str(), Some("advisor_report"));
        assert!(!out.get("layers").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn default_type_is_advisor() {
        let out = handle_request(r#"{"network":"alexnet"}"#).unwrap();
        assert_eq!(out.get("type").unwrap().as_str(), Some("advisor_report"));
    }

    #[test]
    fn check_line_answers() {
        let out = handle_request(r#"{"type":"check","n":4096,"m_acc":12}"#).unwrap();
        assert_eq!(out.get("type").unwrap().as_str(), Some("check_report"));
        assert!(out.get("min_m_acc").unwrap().as_f64().is_some());
        assert!(out.get("suitable").unwrap().as_bool().is_some());
    }

    #[test]
    fn test_line_answers_with_measured_sweep() {
        let out = handle_request(r#"{"type":"test","n":512,"m_accs":[6,12],"trials":16}"#).unwrap();
        assert_eq!(out.get("type").unwrap().as_str(), Some("test_report"));
        let points = out.get("points").unwrap().as_arr().unwrap();
        assert_eq!(points.len(), 2);
        assert!(points[0].get("measured").unwrap().as_f64().is_some());
    }

    /// Satellite requirement: a degenerate ensemble used to come back as
    /// a silent NaN VRR — it must now be a structured error line.
    #[test]
    fn degenerate_test_request_is_a_structured_error_line() {
        let input = "{\"type\":\"test\",\"n\":64,\"m_acc\":8,\"trials\":1,\"id\":\"deg\"}\n";
        let mut out = Vec::new();
        let stats = serve(input.as_bytes(), &mut out).unwrap();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.errors, 1);
        let err = Json::parse(String::from_utf8(out).unwrap().lines().next().unwrap()).unwrap();
        let obj = err.get("error").unwrap();
        assert_eq!(obj.get("kind").unwrap().as_str(), Some("invalid"));
        assert!(obj
            .get("message")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("at least 2"));
        assert_eq!(err.get("id").unwrap().as_str(), Some("deg"));
    }

    #[test]
    fn errors_are_lines_not_failures() {
        let input = "{\"network\":\"resnet32\"}\nnot json\n\n{\"network\":\"resnet18\"}\n";
        let mut out = Vec::new();
        let stats = serve(input.as_bytes(), &mut out).unwrap();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.errors, 1);
        let lines: Vec<&str> = std::str::from_utf8(&out).unwrap().lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(Json::parse(lines[0]).unwrap().get("layers").is_some());
        assert!(Json::parse(lines[2]).unwrap().get("layers").is_some());
        // The error line is structured, with the legacy string alongside.
        let err = Json::parse(lines[1]).unwrap();
        let obj = err.get("error").unwrap();
        assert_eq!(obj.get("kind").unwrap().as_str(), Some("parse"));
        assert_eq!(
            err.get("message").unwrap().as_str(),
            obj.get("message").unwrap().as_str()
        );
    }

    #[test]
    fn error_kinds_cover_the_failure_paths() {
        let kind = |line: &str| {
            let reply = handle_line(line, None);
            assert!(reply.failed);
            Json::parse(&reply.line)
                .unwrap()
                .get("error")
                .unwrap()
                .get("kind")
                .unwrap()
                .as_str()
                .unwrap()
                .to_string()
        };
        assert_eq!(kind("not json"), "parse");
        assert_eq!(kind("[1,2]"), "invalid");
        assert_eq!(kind(r#"{"type":"frobnicate"}"#), "invalid");
        assert_eq!(kind(r#"{"network":"not_a_net"}"#), "invalid");
        assert_eq!(kind(r#"{"type":"__panic"}"#), "panic");
        assert_eq!(kind(r#"{"v":2}"#), "invalid");
    }

    #[test]
    fn unknown_type_is_an_error_line() {
        let mut out = Vec::new();
        let stats = serve("{\"type\":\"frobnicate\"}\n".as_bytes(), &mut out).unwrap();
        assert_eq!(stats.errors, 1);
        assert!(String::from_utf8(out).unwrap().contains("unknown request type"));
    }

    #[test]
    fn request_type_labels_cover_dispatch() {
        assert_eq!(handle_line("not json", None).ty, "invalid");
        assert_eq!(handle_line("[1,2]", None).ty, "invalid");
        assert_eq!(handle_line(r#"{"type":3}"#, None).ty, "invalid");
        assert_eq!(handle_line(r#"{"type":"nope"}"#, None).ty, "unknown");
        assert_eq!(handle_line(r#"{"network":"resnet32"}"#, None).ty, "advisor");
        assert_eq!(handle_line(r#"{"type":"train"}"#, None).ty, "train");
        assert_eq!(handle_line(r#"{"type":"check","n":64}"#, None).ty, "check");
        assert_eq!(
            handle_line(r#"{"type":"test","n":64,"m_acc":8,"trials":4}"#, None).ty,
            "test"
        );
        assert_eq!(handle_line(r#"{"type":"__panic"}"#, None).ty, "test");
    }

    #[test]
    fn id_is_echoed_in_replies_and_errors() {
        let ok = handle_request(r#"{"network":"resnet32","id":"req-7"}"#).unwrap();
        assert_eq!(ok.get("id").unwrap().as_str(), Some("req-7"));
        // Non-string ids echo verbatim too.
        let reply = handle_line(r#"{"type":"frobnicate","id":42}"#, None);
        assert_eq!(
            Json::parse(&reply.line).unwrap().get("id").unwrap().as_f64(),
            Some(42.0)
        );
        // A bad envelope version still correlates by id.
        let reply = handle_line(r#"{"v":9,"id":"v-check"}"#, None);
        let err = Json::parse(&reply.line).unwrap();
        assert_eq!(err.get("id").unwrap().as_str(), Some("v-check"));
        assert!(err.get("message").unwrap().as_str().unwrap().contains("v1"));
    }

    #[test]
    fn envelope_version_one_is_accepted() {
        let out = handle_request(r#"{"v":1,"network":"resnet32"}"#).unwrap();
        assert_eq!(out.get("type").unwrap().as_str(), Some("advisor_report"));
        assert!(handle_request(r#"{"v":2,"network":"resnet32"}"#).is_err());
        // null v means v1 as well.
        assert!(handle_request(r#"{"v":null,"network":"resnet32"}"#).is_ok());
    }

    #[test]
    fn panic_is_isolated_and_counted() {
        let input = "{\"network\":\"resnet32\"}\n{\"type\":\"__panic\"}\n{\"network\":\"alexnet\"}\n";
        let mut out = Vec::new();
        let stats = serve(input.as_bytes(), &mut out).unwrap();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.errors, 1);
        assert_eq!(stats.panics, 1);
        assert_eq!(stats.timeouts, 0);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        let err = Json::parse(lines[1]).unwrap();
        assert_eq!(
            err.get("error").unwrap().get("kind").unwrap().as_str(),
            Some("panic")
        );
        assert!(err
            .get("message")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("injected panic"));
    }

    #[test]
    fn concurrent_output_matches_sequential() {
        let mut input = String::new();
        for i in 0..4 {
            let net = ["resnet32", "resnet18", "alexnet"][i % 3];
            input.push_str(&format!(
                "{{\"type\":\"advisor\",\"network\":\"{net}\",\"id\":{i}}}\n"
            ));
        }
        input.push_str("{\"type\":\"check\",\"n\":1000,\"m_acc\":9}\n");
        input.push_str("not json\n");
        input.push_str("{\"type\":\"frobnicate\",\"id\":\"x\"}\n");
        input.push_str("{\"type\":\"__panic\"}\n");

        let mut seq_out = Vec::new();
        let seq_stats = serve_with(
            input.as_bytes(),
            &mut seq_out,
            &ServeOptions {
                workers: 1,
                ..Default::default()
            },
        )
        .unwrap();
        let mut con_out = Vec::new();
        let con_stats = serve_with(
            input.as_bytes(),
            &mut con_out,
            &ServeOptions {
                workers: 4,
                queue_depth: 2,
                timeout_ms: None,
            },
        )
        .unwrap();
        assert_eq!(seq_out, con_out, "pipeline output must be byte-identical");
        assert_eq!(seq_stats, con_stats);
        assert_eq!(con_stats.requests, 8);
        assert_eq!(con_stats.errors, 3);
        assert_eq!(con_stats.panics, 1);
    }

    #[test]
    fn timeout_degrades_to_structured_error() {
        let input = "{\"type\":\"__sleep\",\"ms\":5000,\"id\":\"slow\"}\n";
        let mut out = Vec::new();
        let stats = serve_with(
            input.as_bytes(),
            &mut out,
            &ServeOptions {
                workers: 1,
                queue_depth: 8,
                timeout_ms: Some(20),
            },
        )
        .unwrap();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.errors, 1);
        assert_eq!(stats.timeouts, 1);
        let err = Json::parse(String::from_utf8(out).unwrap().lines().next().unwrap()).unwrap();
        assert_eq!(
            err.get("error").unwrap().get("kind").unwrap().as_str(),
            Some("timeout")
        );
        assert_eq!(err.get("id").unwrap().as_str(), Some("slow"));
    }

    #[test]
    fn gate_bounds_and_closes() {
        let g = Gate::new(2);
        g.acquire();
        g.acquire();
        // Full: a third acquire would block — release first, then retake.
        g.release();
        g.acquire();
        // Close unblocks everyone; acquires become no-ops.
        g.close();
        g.acquire();
        g.acquire();
    }

    /// Satellite requirement: each response line reaches the consumer as
    /// soon as it is written (flush after every line), on both paths.
    #[test]
    fn output_is_flushed_per_line() {
        struct CountingWriter {
            flushes: usize,
            buf: Vec<u8>,
        }
        impl Write for CountingWriter {
            fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
                self.buf.extend_from_slice(data);
                Ok(data.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                self.flushes += 1;
                Ok(())
            }
        }
        let input = "{\"network\":\"resnet32\"}\nbad\n{\"network\":\"alexnet\"}\n";
        for workers in [1usize, 3] {
            let mut w = CountingWriter {
                flushes: 0,
                buf: Vec::new(),
            };
            let stats = serve_with(
                input.as_bytes(),
                &mut w,
                &ServeOptions {
                    workers,
                    ..Default::default()
                },
            )
            .unwrap();
            assert_eq!(stats.requests, 3);
            // One flush per response line, error lines included.
            assert!(w.flushes >= 3, "workers={workers} flushes={}", w.flushes);
            assert_eq!(String::from_utf8(w.buf).unwrap().lines().count(), 3);
        }
    }
}
