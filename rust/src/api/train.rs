//! Typed training requests: the native bit-accurate trainer behind the
//! same request/response discipline as the advisor. A [`TrainRequest`]
//! names the task (synthetic-classification dimensions), the
//! [`PrecisionPolicy`] and a [`PlanSpec`] (baseline, uniform width, or
//! the solver's prediction under a precision perturbation); resolving it
//! yields the concrete [`PrecisionPlan`] plus the chosen per-GEMM widths,
//! and running it returns a [`TrainReport`] with the metric trace.

use anyhow::{bail, ensure, Context, Result};

use super::cache;
use super::error::ApiError;
use super::policy::PrecisionPolicy;
use crate::data::synth::{generate, Dataset, SynthSpec};
use crate::trainer::metrics::RunMetrics;
use crate::trainer::native::{NativeTrainer, PrecisionPlan, TrainConfig};
use crate::util::json::Json;
use crate::vrr::solver::perturbed;

/// How to pick the three GEMM accumulator widths.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanSpec {
    /// Full-precision control arm (ideal accumulation, no quantization).
    Baseline,
    /// One reduced width for all three GEMMs.
    Uniform { m_acc: u32 },
    /// The solver's per-GEMM prediction, shifted by a precision
    /// perturbation (paper Fig. 6: `pp = 0` is the prediction, `-1` one
    /// bit fewer, …).
    Predicted { pp: i32 },
}

/// One training query for the native reduced-precision trainer.
#[derive(Clone, Debug)]
pub struct TrainRequest {
    pub policy: PrecisionPolicy,
    pub plan: PlanSpec,
    /// Input dimensionality — also the FWD accumulation length.
    pub dim: usize,
    /// Class count — also the BWD accumulation length.
    pub classes: usize,
    pub hidden: usize,
    pub steps: usize,
    /// Mini-batch size — also the GRAD accumulation length.
    pub batch: usize,
    pub seed: u64,
    pub data_seed: u64,
    pub n_train: usize,
    pub n_test: usize,
    pub noise: f64,
}

impl Default for TrainRequest {
    fn default() -> Self {
        TrainRequest {
            policy: PrecisionPolicy::paper(),
            plan: PlanSpec::Predicted { pp: 0 },
            dim: 256,
            classes: 10,
            hidden: 64,
            steps: 300,
            batch: 32,
            seed: 42,
            data_seed: 1234,
            n_train: 2048,
            n_test: 512,
            noise: 1.0,
        }
    }
}

/// The per-GEMM accumulator mantissa widths a plan resolved to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanWidths {
    pub fwd: u32,
    pub bwd: u32,
    pub grad: u32,
}

/// A request with its plan made concrete (solver already consulted).
#[derive(Clone, Debug)]
pub struct ResolvedTrain {
    pub req: TrainRequest,
    pub plan: PrecisionPlan,
    /// `None` for the baseline arm (widths are the ideal 52 bits).
    pub widths: Option<PlanWidths>,
}

impl TrainRequest {
    /// Validate and turn the [`PlanSpec`] into a concrete plan. The
    /// `Predicted` arm solves the three GEMM accumulations (FWD over
    /// `dim`, BWD over `classes`, GRAD over `batch`) through the
    /// process-wide memoized solver.
    pub fn resolve(&self) -> Result<ResolvedTrain> {
        self.policy.validate()?;
        ensure!(self.dim > 0, "dim must be positive");
        ensure!(self.classes > 1, "classes must be at least 2");
        ensure!(self.steps > 0, "steps must be positive");
        ensure!(self.batch > 0, "batch must be positive");
        ensure!(self.hidden > 0, "hidden must be positive");
        let (plan, widths) = match self.plan {
            PlanSpec::Baseline => (super::policy::baseline_plan(), None),
            PlanSpec::Uniform { m_acc } => {
                ensure!(
                    (1..=52).contains(&m_acc),
                    "uniform m_acc must be in 1..=52, got {m_acc}"
                );
                (
                    self.policy.plan_uniform(m_acc),
                    Some(PlanWidths {
                        fwd: m_acc,
                        bwd: m_acc,
                        grad: m_acc,
                    }),
                )
            }
            PlanSpec::Predicted { pp } => {
                let t = self.policy.nzr_triple();
                let fwd = perturbed(
                    cache::min_m_acc(&self.policy.accum_spec(self.dim, t.fwd)),
                    pp,
                );
                let bwd = perturbed(
                    cache::min_m_acc(&self.policy.accum_spec(self.classes, t.bwd)),
                    pp,
                );
                let grad = perturbed(
                    cache::min_m_acc(&self.policy.accum_spec(self.batch, t.grad)),
                    pp,
                );
                (
                    self.policy.plan_per_gemm(fwd, bwd, grad),
                    Some(PlanWidths { fwd, bwd, grad }),
                )
            }
        };
        Ok(ResolvedTrain {
            req: self.clone(),
            plan,
            widths,
        })
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("type", "train");
        j.set("policy", self.policy.to_json());
        let mut plan = Json::obj();
        match self.plan {
            PlanSpec::Baseline => {
                plan.set("kind", "baseline");
            }
            PlanSpec::Uniform { m_acc } => {
                plan.set("kind", "uniform");
                plan.set("m_acc", m_acc);
            }
            PlanSpec::Predicted { pp } => {
                plan.set("kind", "predicted");
                plan.set("pp", pp as i64);
            }
        }
        j.set("plan", plan);
        j.set("dim", self.dim);
        j.set("classes", self.classes);
        j.set("hidden", self.hidden);
        j.set("steps", self.steps);
        j.set("batch", self.batch);
        j.set("seed", self.seed as i64);
        j.set("data_seed", self.data_seed as i64);
        j.set("n_train", self.n_train);
        j.set("n_test", self.n_test);
        j.set("noise", self.noise);
        j
    }

    /// Parse the wire form; absent or null fields keep the defaults,
    /// type-mismatched fields are errors (never silently defaulted).
    pub fn from_json(j: &Json) -> Result<TrainRequest> {
        let mut req = TrainRequest::default();
        if let Some(p) = j.get("policy") {
            req.policy = PrecisionPolicy::from_json(p).context("parsing 'policy'")?;
        }
        if let Some(p) = j.get("plan") {
            if !matches!(p, Json::Obj(_)) {
                bail!("'plan' must be an object like {{\"kind\":\"baseline\"}}, got {p}");
            }
            let kind = match p.get("kind") {
                None => "predicted",
                Some(Json::Str(s)) => s.as_str(),
                Some(other) => bail!("'plan.kind' must be a string, got {other}"),
            };
            req.plan = match kind {
                "baseline" => PlanSpec::Baseline,
                "uniform" => PlanSpec::Uniform {
                    m_acc: super::opt_num(p, "m_acc")?
                        .context("uniform plan needs 'm_acc'")?
                        as u32,
                },
                "predicted" => PlanSpec::Predicted {
                    pp: super::opt_num(p, "pp")?.unwrap_or(0.0) as i32,
                },
                other => bail!("unknown plan kind '{other}' (baseline|uniform|predicted)"),
            };
        }
        let num = |k: &str, field: &mut usize| -> Result<()> {
            if let Some(v) = super::opt_num(j, k)? {
                *field = v as usize;
            }
            Ok(())
        };
        num("dim", &mut req.dim)?;
        num("classes", &mut req.classes)?;
        num("hidden", &mut req.hidden)?;
        num("steps", &mut req.steps)?;
        num("batch", &mut req.batch)?;
        num("n_train", &mut req.n_train)?;
        num("n_test", &mut req.n_test)?;
        if let Some(v) = super::opt_num(j, "seed")? {
            req.seed = v as u64;
        }
        if let Some(v) = super::opt_num(j, "data_seed")? {
            req.data_seed = v as u64;
        }
        if let Some(v) = super::opt_num(j, "noise")? {
            req.noise = v;
        }
        Ok(req)
    }
}

impl TrainRequest {
    /// The synthetic-task specification this request trains on. Sweeps
    /// whose arms share the data fields can [`generate`] once and pass
    /// the datasets to [`ResolvedTrain::run_on`] instead of regenerating
    /// per arm.
    pub fn dataset_spec(&self) -> SynthSpec {
        SynthSpec {
            n_train: self.n_train,
            n_test: self.n_test,
            dim: self.dim,
            classes: self.classes,
            noise: self.noise,
            seed: self.data_seed,
        }
    }
}

impl ResolvedTrain {
    /// Generate the synthetic task, train the native trainer under the
    /// resolved plan and evaluate on the held-out split.
    pub fn run(&self) -> TrainReport {
        let (train, test) = generate(&self.req.dataset_spec());
        self.run_on(&train, &test)
    }

    /// [`ResolvedTrain::run`] on caller-provided train/test splits (for
    /// sweeps that share one deterministic dataset across arms).
    pub fn run_on(&self, train: &Dataset, test: &Dataset) -> TrainReport {
        self.run_on_with_deadline(train, test, None)
            .expect("deadline-free run cannot time out")
    }

    /// [`ResolvedTrain::run`] under an optional cooperative deadline (the
    /// serve `--timeout-ms` path). The step loop checks the deadline
    /// between steps; once passed, the run stops and this returns a
    /// timeout [`ApiError`] instead of a report.
    pub fn run_with_deadline(
        &self,
        deadline: Option<std::time::Instant>,
    ) -> Result<TrainReport, ApiError> {
        let (train, test) = generate(&self.req.dataset_spec());
        self.run_on_with_deadline(&train, &test, deadline)
    }

    /// [`ResolvedTrain::run_with_deadline`] on caller-provided splits.
    pub fn run_on_with_deadline(
        &self,
        train: &Dataset,
        test: &Dataset,
        deadline: Option<std::time::Instant>,
    ) -> Result<TrainReport, ApiError> {
        let _tspan = if crate::telemetry::trace::enabled() {
            crate::telemetry::trace::TraceSpan::enter("train.run")
                .attr("steps", self.req.steps.to_string())
                .attr("dim", self.req.dim.to_string())
        } else {
            crate::telemetry::trace::TraceSpan::noop()
        };
        let _span = if crate::telemetry::enabled() {
            crate::telemetry::counter("abws_train_runs_total").inc();
            crate::telemetry::Span::enter(crate::telemetry::histogram("abws_train_run_wall_ns"))
        } else {
            crate::telemetry::Span::noop()
        };
        let r = &self.req;
        let cfg = TrainConfig {
            hidden: r.hidden,
            steps: r.steps,
            batch: r.batch,
            seed: r.seed,
            deadline,
            ..Default::default()
        };
        let mut trainer = NativeTrainer::new(r.dim, r.classes, self.plan, cfg);
        let metrics = trainer.train(train);
        if metrics.deadline_exceeded {
            return Err(ApiError::timeout(format!(
                "train request exceeded its deadline after {} of {} steps",
                metrics.steps.len(),
                r.steps
            )));
        }
        let test_acc = trainer.evaluate(test);
        Ok(TrainReport {
            widths: self.widths,
            metrics,
            test_acc,
        })
    }
}

/// The training answer: resolved widths, the metric trace, held-out
/// accuracy.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub widths: Option<PlanWidths>,
    pub metrics: RunMetrics,
    pub test_acc: f64,
}

impl TrainReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("type", "train_report");
        match self.widths {
            Some(w) => {
                j.set("m_fwd", w.fwd);
                j.set("m_bwd", w.bwd);
                j.set("m_grad", w.grad);
            }
            None => {
                j.set("m_fwd", Json::Null);
                j.set("m_bwd", Json::Null);
                j.set("m_grad", Json::Null);
            }
        }
        j.set("steps_run", self.metrics.steps.len());
        j.set(
            "final_loss",
            self.metrics.final_loss().unwrap_or(f64::NAN),
        );
        j.set("test_acc", self.test_acc);
        j.set("diverged", self.metrics.diverged);
        j.set(
            "loss_curve",
            self.metrics
                .to_json()
                .get("loss")
                .cloned()
                .unwrap_or_else(|| Json::Arr(Vec::new())),
        );
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> TrainRequest {
        TrainRequest {
            dim: 32,
            classes: 4,
            hidden: 16,
            steps: 25,
            batch: 16,
            n_train: 128,
            n_test: 64,
            ..Default::default()
        }
    }

    #[test]
    fn predicted_plan_matches_direct_solve() {
        let req = tiny();
        let resolved = req.resolve().unwrap();
        let w = resolved.widths.unwrap();
        let direct = crate::vrr::solver::min_m_acc(&req.policy.accum_spec(32, 1.0));
        assert_eq!(w.fwd, direct);
        assert_eq!(resolved.plan.fwd.acc.man_bits, w.fwd);
    }

    #[test]
    fn uniform_and_baseline_resolve() {
        let mut req = tiny();
        req.plan = PlanSpec::Uniform { m_acc: 12 };
        let w = req.resolve().unwrap().widths.unwrap();
        assert_eq!((w.fwd, w.bwd, w.grad), (12, 12, 12));
        req.plan = PlanSpec::Baseline;
        assert!(req.resolve().unwrap().widths.is_none());
        req.plan = PlanSpec::Uniform { m_acc: 0 };
        assert!(req.resolve().is_err());
    }

    #[test]
    fn run_produces_metrics() {
        let mut req = tiny();
        req.plan = PlanSpec::Uniform { m_acc: 12 };
        let report = req.resolve().unwrap().run();
        assert_eq!(report.metrics.steps.len(), 25);
        assert!((0.0..=1.0).contains(&report.test_acc));
        let j = report.to_json();
        assert_eq!(j.get("steps_run").unwrap().as_f64(), Some(25.0));
        assert!(j.get("loss_curve").unwrap().as_arr().unwrap().len() == 25);
    }

    #[test]
    fn expired_deadline_yields_timeout_error() {
        let mut req = tiny();
        req.plan = PlanSpec::Uniform { m_acc: 12 };
        let resolved = req.resolve().unwrap();
        let past = std::time::Instant::now() - std::time::Duration::from_millis(1);
        let err = resolved.run_with_deadline(Some(past)).unwrap_err();
        assert_eq!(err.kind, crate::api::error::ErrorKind::Timeout);
        assert!(err.message.contains("deadline"));
        // No deadline at all still succeeds on the same resolved plan.
        assert!(resolved.run_with_deadline(None).is_ok());
    }

    #[test]
    fn type_mismatched_fields_error_instead_of_defaulting() {
        // A string-typed number (common JSON-producer mistake) must be an
        // error line from `serve`, not a silently-defaulted run.
        let j = Json::parse(r#"{"type":"train","steps":"100"}"#).unwrap();
        assert!(TrainRequest::from_json(&j).is_err());
        let p = Json::parse(r#"{"m_p":"7"}"#).unwrap();
        assert!(PrecisionPolicy::from_json(&p).is_err());
        let plan = Json::parse(r#"{"plan":{"kind":"uniform","m_acc":"8"}}"#).unwrap();
        assert!(TrainRequest::from_json(&plan).is_err());
        // A plan that isn't an object (or whose kind isn't a string) must
        // not silently become Predicted{pp:0}.
        let s = Json::parse(r#"{"plan":"baseline"}"#).unwrap();
        assert!(TrainRequest::from_json(&s).is_err());
        let k = Json::parse(r#"{"plan":{"kind":123}}"#).unwrap();
        assert!(TrainRequest::from_json(&k).is_err());
    }

    #[test]
    fn json_roundtrip() {
        let mut req = tiny();
        req.plan = PlanSpec::Predicted { pp: -2 };
        let text = req.to_json().to_string();
        let back = TrainRequest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string(), text);
        assert_eq!(back.plan, PlanSpec::Predicted { pp: -2 });
        assert_eq!(back.dim, 32);
    }
}
