//! The `abws` command-line interface — a thin shell over [`crate::api`].
//!
//! ```text
//! abws predict [--net all|resnet32|resnet18|alexnet] [--chunk 64] [--mp 5]
//! abws vrr --macc 12 --n 4096 [--mp 5] [--chunk 64] [--nzr 0.5]
//!          [--empirical [--maccs 5,8,12] [--trials 96] [--seed S]]
//! abws area
//! abws mc [--n 16384] [--maccs 5,6,8] [--trials 256] [--chunk 64]
//! abws train [--mode native|aot] [--macc 12 | --pp -1] [--chunk 64]
//!            [--steps 300] [--dim 256] [--hidden 64] [--seed 42]
//! abws serve [--workers N] [--queue-depth N] [--timeout-ms N] [--telemetry]
//!            [--telemetry-interval-ms N] [--trace-out trace.json]
//! abws metrics [--format table|json|prom] [--no-demo]
//! abws trace [--out trace.json] [--seed S]
//! abws list
//! abws info
//! ```
//!
//! `serve` is the batch front door: it reads newline-delimited JSON
//! requests from stdin and writes one JSON report per line to stdout.
//!
//! ```text
//! $ echo '{"type":"advisor","network":"resnet32","policy":{"chunk":64}}' | abws serve
//! {"chunk":64,"gemms":["FWD","BWD","GRAD"],"groups":[...],"layers":[...],
//!  "network":"CIFAR-10 ResNet-32","type":"advisor_report"}
//! ```

use anyhow::{anyhow, bail, ensure, Result};

use crate::api::{self, PlanSpec, PrecisionPolicy, TrainRequest};
use crate::coordinator::registry;
use crate::hw::fpu::{FpuAreaModel, FpuConfig};
use crate::hw::report;
use crate::mc::validate;
use crate::util::argparse::Args;
use crate::vrr;

/// Entry point used by `main.rs`; returns the process exit code.
pub fn run(args: Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("predict") => cmd_predict(&args),
        Some("vrr") => cmd_vrr(&args),
        Some("area") => cmd_area(),
        Some("mc") => cmd_mc(&args),
        Some("train") => cmd_train(&args),
        Some("serve") => cmd_serve(&args),
        Some("metrics") => cmd_metrics(&args),
        Some("trace") => cmd_trace(&args),
        Some("list") => {
            print!("{}", registry::render_catalog());
            Ok(())
        }
        Some("info") => cmd_info(),
        Some(other) => bail!("unknown command '{other}'\n{}", USAGE),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "usage: abws <predict|vrr|area|mc|train|serve|metrics|trace|list|info> [options]
  predict  — Table 1: per-layer-group accumulation precision predictions
  vrr      — evaluate VRR / v(n) for one accumulation setup
             (--empirical measures it with the Monte-Carlo engine instead:
              --maccs sweeps several widths against one drawn ensemble)
  area     — Fig 1b: FPU area model ladder
  mc       — Monte-Carlo validation of the VRR formulas
  train    — reduced-precision training run (native bit-accurate or AOT/PJRT)
  serve    — batch mode: NDJSON advisor/train/check requests on stdin -> reports on stdout
             (--workers N pools request execution, 0 = one per core; replies stay
              in input order. --queue-depth N bounds read-ahead (default 128).
              --timeout-ms N gives every request a deadline.
              --telemetry emits JSON metrics snapshots to stderr, periodically
              (--telemetry-interval-ms, default 10000) and once at shutdown.
              --trace-out PATH enables request tracing: the flight recorder is
              dumped as chrome://tracing JSON on request timeout/panic and
              drained to PATH on clean exit)
  metrics  — exercise the stack and print the telemetry snapshot
             (--format table|json|prom; --no-demo to skip the workload)
  trace    — run the demo workload with tracing on and dump the span tree
             as chrome://tracing JSON (--out FILE, default stdout; --seed S
             fixes trace/span ids)
  list     — catalog of reproducible experiments
  info     — PJRT runtime info";

/// Parse `--chunk` into an optional chunk size with a usable error
/// (previously `.parse().unwrap()` panicked on bad input).
fn parse_chunk(args: &Args) -> Result<Option<usize>> {
    match args.get("chunk") {
        None => Ok(None),
        Some(s) => {
            let c: usize = s.parse().map_err(|_| {
                anyhow!("--chunk expects a positive integer chunk size, got '{s}' (e.g. --chunk 64)")
            })?;
            ensure!(c >= 1, "--chunk must be at least 1, got {c}");
            Ok(Some(c))
        }
    }
}

fn cmd_predict(args: &Args) -> Result<()> {
    let policy = PrecisionPolicy::builder()
        .m_p(args.get_u32("mp", 5))
        .chunk(parse_chunk(args)?.unwrap_or(64))
        .build()?;
    for report in api::advise_builtin(args.get_or("net", "all"), &policy)? {
        println!("{}", report.render());
        if args.flag("detail") {
            for lp in &report.prediction.layers {
                println!(
                    "  {:<12} {:<12} fwd n={:<8} bwd n={:<8} grad n={:<8}",
                    lp.layer, lp.group, lp.lengths.fwd, lp.lengths.bwd, lp.lengths.grad
                );
            }
        }
    }
    Ok(())
}

/// `abws vrr --empirical`: measure the VRR with the sweep-vectorized
/// Monte-Carlo engine instead of evaluating the closed form — every
/// width in `--maccs` is scored against the *same* drawn ensemble in one
/// engine pass, next to its Theorem 1 / Corollary 1 prediction.
fn cmd_vrr_empirical(args: &Args) -> Result<()> {
    use crate::coordinator::sweep::default_threads;
    use crate::mc::{sweep_vrr, AccumSetup, Ensemble};

    let m_accs = args.get_u32_list("maccs", &[args.get_u32("macc", 12)]);
    for &m in &m_accs {
        ensure!((1..=52).contains(&m), "--maccs entries must be in 1..=52, got {m}");
    }
    let n = args.get_usize("n", 4096);
    let m_p = args.get_u32("mp", 5);
    let chunk = parse_chunk(args)?;
    if let Some(c) = chunk {
        ensure!(c <= n, "--chunk {c} exceeds --n {n}");
    }
    let trials = args.get_usize("trials", 96);
    let seed = args.get_i64("seed", 0x5eed) as u64;
    ensure!(
        args.get("nzr").is_none(),
        "--empirical draws a dense ensemble; --nzr applies to the closed-form path only"
    );
    let ens = Ensemble {
        n,
        m_p,
        e_acc: 6,
        sigma_p: 1.0,
        trials,
        seed,
        threads: default_threads(),
    };
    let grid: Vec<AccumSetup> = m_accs
        .iter()
        .map(|&m| {
            let s = AccumSetup::new(m);
            match chunk {
                Some(c) => s.with_chunk(c),
                None => s,
            }
        })
        .collect();
    let results = sweep_vrr(&ens, &grid)?;
    println!(
        "empirical VRR (n={n}, m_p={m_p}, chunk={}, trials={trials}, seed={seed}):",
        chunk.map(|c| c.to_string()).unwrap_or("-".into())
    );
    println!("{:>6} {:>9} {:>9} {:>8}", "m_acc", "theory", "measured", "|err|");
    for (&m, r) in m_accs.iter().zip(&results) {
        let theory = match chunk {
            Some(c) => vrr::chunking::vrr_chunked_total(m, m_p, n, c),
            None => vrr::theorem::vrr(m, m_p, n),
        };
        println!(
            "{m:>6} {theory:>9.4} {:>9.4} {:>8.4}",
            r.vrr,
            (theory - r.vrr).abs()
        );
    }
    Ok(())
}

fn cmd_vrr(args: &Args) -> Result<()> {
    if args.flag("empirical") {
        return cmd_vrr_empirical(args);
    }
    let m_acc = args.get_u32("macc", 12);
    ensure!(
        (1..=52).contains(&m_acc),
        "--macc must be in 1..=52, got {m_acc}"
    );
    let n = args.get_usize("n", 4096);
    let nzr = args.get_f64("nzr", 1.0);
    let policy = PrecisionPolicy::builder()
        .m_p(args.get_u32("mp", 5))
        .maybe_chunk(parse_chunk(args)?)
        .build()?;
    let spec = policy.checked_accum_spec(n, nzr)?;
    let v = api::cache::vrr(&spec, m_acc);
    let log_v = vrr::variance_lost::log_variance_lost(v, spec.n_eff());
    println!(
        "VRR(m_acc={m_acc}, m_p={}, n={n}, nzr={nzr}, chunk={:?}) = {v:.6}",
        policy.m_p, spec.chunk
    );
    println!("log v(n) = {log_v:.3} (cutoff ln 50 = {:.3})", vrr::CUTOFF_LN);
    println!(
        "suitable: {}; minimum m_acc for this accumulation: {}",
        spec.suitable(m_acc),
        api::cache::min_m_acc(&spec)
    );
    Ok(())
}

fn cmd_area() -> Result<()> {
    let model = FpuAreaModel::default();
    let rows = report::area_rows(&model, &FpuAreaModel::fig1b_configs());
    print!("{}", report::render(&rows));
    let fp8_32 = model.area(&FpuConfig::new(
        crate::softfloat::FpFormat::FP8_152,
        crate::softfloat::FpFormat::FP32,
    ));
    let fp8_16 = model.area(&FpuConfig::new(
        crate::softfloat::FpFormat::FP8_152,
        crate::softfloat::FpFormat::new(6, 9),
    ));
    println!(
        "narrow-accumulator gain (FP8/32 -> FP8/16): {:.2}x",
        fp8_32 / fp8_16
    );
    Ok(())
}

fn cmd_mc(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 16_384);
    let maccs = args.get_u32_list("maccs", &[5, 6, 8, 10]);
    let trials = args.get_usize("trials", 256);
    let chunk = parse_chunk(args)?;
    let seed = args.get_i64("seed", 0x5eed) as u64;
    let pts = validate::validate_grid(&maccs, &[n], chunk, trials, seed)?;
    print!("{}", validate::render(&pts));
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let plan = if let Some(m) = args.get("macc") {
        PlanSpec::Uniform {
            m_acc: m.parse().map_err(|_| {
                anyhow!("--macc expects an integer mantissa width, got '{m}' (e.g. --macc 12)")
            })?,
        }
    } else {
        PlanSpec::Predicted {
            pp: args.get_i64("pp", 0) as i32,
        }
    };
    let req = TrainRequest {
        policy: PrecisionPolicy::builder()
            .maybe_chunk(parse_chunk(args)?)
            .build()?,
        plan,
        dim: args.get_usize("dim", 256),
        hidden: args.get_usize("hidden", 64),
        steps: args.get_usize("steps", 300),
        batch: args.get_usize("batch", 32),
        seed: args.get_i64("seed", 42) as u64,
        data_seed: args.get_i64("data-seed", 1234) as u64,
        ..Default::default()
    };

    // Precision plan: explicit --macc, or the solver's prediction (+ --pp).
    let resolved = req.resolve()?;
    if let (PlanSpec::Predicted { pp }, Some(w)) = (req.plan, &resolved.widths) {
        println!(
            "predicted m_acc (pp={pp}): fwd={} bwd={} grad={}",
            w.fwd, w.bwd, w.grad
        );
    }

    match args.get_or("mode", "native") {
        "native" => {
            let report = resolved.run();
            report_run(&report.metrics, report.test_acc, req.steps);
        }
        "aot" => run_aot(args, &req)?,
        other => bail!("unknown mode '{other}' (native|aot)"),
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn run_aot(args: &Args, req: &TrainRequest) -> Result<()> {
    use crate::data::synth::{generate, SynthSpec};
    use crate::trainer::native::{NativeTrainer, TrainConfig};

    let store = crate::runtime::ArtifactStore::open(args.get_or("artifacts", "artifacts"))?;
    store.verify()?;
    let rt = crate::runtime::Runtime::cpu()?;
    let variant = args.get_or("variant", "baseline").to_string();
    let mut exec = crate::runtime::TrainStepExecutor::new(&rt, &store, &variant, req.seed)?;
    let d = exec.dims;
    let (train, test) = generate(&SynthSpec {
        dim: d.dim,
        classes: d.classes,
        n_train: req.n_train,
        n_test: req.n_test,
        noise: req.noise,
        seed: req.data_seed,
    });
    let m = exec.train(&train, req.steps)?;
    // Evaluate with the native forward on the trained params.
    let (w1, w2) = exec.params()?;
    let cfg = TrainConfig {
        hidden: req.hidden,
        steps: req.steps,
        batch: req.batch,
        seed: req.seed,
        ..Default::default()
    };
    let mut nt = NativeTrainer::new(d.dim, d.classes, api::baseline_plan(), cfg);
    nt.w1 = w1;
    nt.w2 = w2;
    let test_acc = nt.evaluate(&test);
    report_run(&m, test_acc, req.steps);
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn run_aot(_args: &Args, _req: &TrainRequest) -> Result<()> {
    bail!(
        "this build has no PJRT runtime — rebuild with `--features pjrt` \
         (and the vendored `xla` dependency) to run AOT artifacts"
    )
}

fn report_run(m: &crate::trainer::RunMetrics, test_acc: f64, steps: usize) {
    for r in m.steps.iter().step_by((steps / 20).max(1)) {
        println!(
            "step {:>5}  loss {:>9.4}  train-acc {:>6.3}",
            r.step, r.loss, r.train_acc
        );
    }
    if let Some(r) = m.steps.last() {
        println!(
            "final     loss {:>9.4}  train-acc {:>6.3}",
            r.loss, r.train_acc
        );
    }
    println!("test-acc {test_acc:.4}  diverged: {}", m.diverged);
}

/// Parse an optional integer flag with a usable error (the panicking
/// `Args::get_usize` is wrong for user-facing serve flags).
fn parse_count(args: &Args, name: &str) -> Result<Option<u64>> {
    match args.get(name) {
        None => Ok(None),
        Some(s) => s.parse().map(Some).map_err(|_| {
            anyhow!("--{name} expects a non-negative integer, got '{s}' (e.g. --{name} 4)")
        }),
    }
}

/// Assemble [`api::ServeOptions`] from serve's command-line flags.
fn serve_options(args: &Args) -> Result<api::ServeOptions> {
    let workers = match parse_count(args, "workers")? {
        // 0 is an explicit "one per core" request.
        Some(0) => api::default_workers(),
        Some(w) => w as usize,
        None => 1,
    };
    let queue_depth = match parse_count(args, "queue-depth")? {
        Some(q) => {
            ensure!(q >= 1, "--queue-depth must be at least 1");
            q as usize
        }
        None => 128,
    };
    let timeout_ms = match parse_count(args, "timeout-ms")? {
        Some(t) => {
            ensure!(t >= 1, "--timeout-ms must be at least 1");
            Some(t)
        }
        None => None,
    };
    Ok(api::ServeOptions {
        workers,
        queue_depth,
        timeout_ms,
    })
}

fn cmd_serve(args: &Args) -> Result<()> {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let opts = serve_options(args)?;
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    if let Some(path) = &trace_out {
        crate::telemetry::trace::set_dump_path(Some(path.clone()));
        crate::telemetry::trace::set_enabled(true);
    }
    // Periodic telemetry emitter: one JSON snapshot line to stderr per
    // interval while serving. Snapshots go to stderr so they never
    // interleave with the NDJSON report stream on stdout.
    let telemetry_on = args.flag("telemetry");
    let interval_ms = match parse_count(args, "telemetry-interval-ms")? {
        Some(i) => {
            ensure!(i >= 1, "--telemetry-interval-ms must be at least 1");
            i
        }
        None => 10_000,
    };
    let stop = Arc::new(AtomicBool::new(false));
    let emitter = telemetry_on.then(|| {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            // Sleep in short slices so shutdown never waits out a full
            // interval behind a parked emitter.
            let mut elapsed = 0u64;
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(std::time::Duration::from_millis(interval_ms.min(50)));
                elapsed += interval_ms.min(50);
                if elapsed >= interval_ms {
                    elapsed = 0;
                    eprintln!("{}", crate::telemetry::snapshot().to_json());
                }
            }
        })
    });
    let stdout = std::io::stdout();
    // `StdinLock` is not `Send` (the reader thread needs to own its
    // input), so wrap the unlocked handle in our own buffer.
    let input = std::io::BufReader::new(std::io::stdin());
    let result = api::serve_with(input, stdout.lock(), &opts);
    stop.store(true, Ordering::Relaxed);
    if let Some(handle) = emitter {
        let _ = handle.join();
    }
    let stats = result?;
    eprintln!(
        "served {} request(s), {} error(s) ({} timeout(s), {} panic(s))",
        stats.requests, stats.errors, stats.timeouts, stats.panics
    );
    // Shutdown always flushes one last snapshot: a fast-exiting stdin
    // (piped batch input) can beat the emitter's first interval.
    if telemetry_on {
        eprintln!("{}", crate::telemetry::snapshot().to_json());
    }
    // Drain the flight recorder on clean exit too — mid-run dumps only
    // happen on request timeout/panic.
    if let Some(path) = &trace_out {
        match crate::telemetry::trace::drain_to_file(path) {
            Ok(n) => eprintln!("wrote {n} trace span(s) to {}", path.display()),
            Err(e) => eprintln!("trace dump to {} failed: {e}", path.display()),
        }
    }
    Ok(())
}

/// `abws metrics`: run a small representative workload through every
/// instrumented subsystem (unless `--no-demo`), then print the snapshot.
fn cmd_metrics(args: &Args) -> Result<()> {
    if !args.flag("no-demo") {
        exercise_stack()?;
    }
    let snap = crate::telemetry::snapshot();
    match args.get_or("format", "table") {
        "table" => print!("{}", snap.render()),
        "json" => println!("{}", snap.to_json()),
        "prom" => print!("{}", snap.prometheus()),
        other => bail!("unknown format '{other}' (table|json|prom)"),
    }
    Ok(())
}

/// `abws trace`: run the demo workload with tracing enabled, then dump
/// the flight recorder as chrome://tracing JSON (open the file via
/// `chrome://tracing` or <https://ui.perfetto.dev>).
fn cmd_trace(args: &Args) -> Result<()> {
    use crate::telemetry::trace;

    if let Some(s) = args.get("seed") {
        let seed: u64 = s
            .parse()
            .map_err(|_| anyhow!("--seed expects an unsigned integer, got '{s}'"))?;
        trace::reseed(seed);
    }
    trace::set_enabled(true);
    let ran = exercise_stack();
    trace::set_enabled(false);
    ran?;
    let spans = trace::drain_spans();
    let json = trace::chrome_trace_json(&spans);
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, json.to_string())?;
            eprintln!("wrote {} trace span(s) to {path}", spans.len());
        }
        None => println!("{json}"),
    }
    Ok(())
}

/// Touch the solver, cache, Monte-Carlo, trainer and serve front door so
/// the demo snapshot shows every metric family.
fn exercise_stack() -> Result<()> {
    let policy = PrecisionPolicy::paper().with_chunk(Some(64));
    // Two advisories: the second is the memoized fast path.
    api::advise_builtin("resnet32", &policy)?;
    api::advise_builtin("resnet32", &policy)?;
    let mut mc = crate::mc::sim::McConfig::new(512, 8).with_trials(8);
    mc.threads = 2;
    crate::mc::sim::empirical_vrr(&mc)?;
    let train = TrainRequest {
        plan: PlanSpec::Uniform { m_acc: 10 },
        dim: 32,
        classes: 4,
        hidden: 8,
        steps: 3,
        batch: 8,
        n_train: 64,
        n_test: 32,
        ..Default::default()
    };
    train.resolve()?.run();
    let mut sink = Vec::new();
    api::serve(
        "{\"type\":\"advisor\",\"network\":\"resnet32\"}\n".as_bytes(),
        &mut sink,
    )?;
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_info() -> Result<()> {
    let rt = crate::runtime::Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_info() -> Result<()> {
    bail!("this build has no PJRT runtime — rebuild with `--features pjrt` for `abws info`")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn chunk_parses_or_errors_cleanly() {
        assert_eq!(parse_chunk(&args(&[])).unwrap(), None);
        assert_eq!(parse_chunk(&args(&["--chunk", "64"])).unwrap(), Some(64));
        let err = parse_chunk(&args(&["--chunk", "banana"])).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("--chunk"), "{msg}");
        assert!(msg.contains("banana"), "{msg}");
        assert!(parse_chunk(&args(&["--chunk", "0"])).is_err());
    }

    #[test]
    fn serve_flags_parse_or_error_cleanly() {
        let o = serve_options(&args(&["serve"])).unwrap();
        assert_eq!(o.workers, 1);
        assert_eq!(o.queue_depth, 128);
        assert_eq!(o.timeout_ms, None);

        let o = serve_options(&args(&[
            "serve",
            "--workers",
            "4",
            "--queue-depth",
            "16",
            "--timeout-ms",
            "250",
        ]))
        .unwrap();
        assert_eq!(o.workers, 4);
        assert_eq!(o.queue_depth, 16);
        assert_eq!(o.timeout_ms, Some(250));

        // --workers 0 means one per core.
        let o = serve_options(&args(&["serve", "--workers", "0"])).unwrap();
        assert!(o.workers >= 1);

        for bad in [
            ["serve", "--workers", "four"],
            ["serve", "--queue-depth", "0"],
            ["serve", "--timeout-ms", "0"],
            ["serve", "--timeout-ms", "-5"],
        ] {
            let e = serve_options(&args(&bad)).unwrap_err();
            assert!(format!("{e:#}").contains("--"), "{bad:?}");
        }
    }

    #[test]
    fn vrr_rejects_out_of_range_macc_and_chunk() {
        assert!(cmd_vrr(&args(&["vrr", "--macc", "0"])).is_err());
        assert!(cmd_vrr(&args(&["vrr", "--macc", "53"])).is_err());
        // chunk larger than n is rejected by checked_accum_spec.
        assert!(cmd_vrr(&args(&["vrr", "--n", "32", "--chunk", "64"])).is_err());
    }

    #[test]
    fn vrr_empirical_sweeps_and_validates() {
        assert!(cmd_vrr(&args(&[
            "vrr",
            "--empirical",
            "--n",
            "256",
            "--trials",
            "8",
            "--maccs",
            "6,12",
        ]))
        .is_ok());
        // Engine-level rejection (trials < 2) surfaces as a CLI error.
        assert!(cmd_vrr(&args(&["vrr", "--empirical", "--n", "64", "--trials", "1"])).is_err());
        assert!(cmd_vrr(&args(&["vrr", "--empirical", "--nzr", "0.5"])).is_err());
        assert!(cmd_vrr(&args(&["vrr", "--empirical", "--maccs", "0,5"])).is_err());
        assert!(cmd_vrr(&args(&["vrr", "--empirical", "--n", "32", "--chunk", "64"])).is_err());
    }

    #[test]
    fn bad_macc_is_an_error_not_a_panic() {
        let e = cmd_train(&args(&["train", "--macc", "noon"])).unwrap_err();
        assert!(format!("{e:#}").contains("--macc"));
    }

    #[test]
    fn unknown_command_lists_usage() {
        let e = run(args(&["frobnicate"])).unwrap_err();
        assert!(format!("{e:#}").contains("usage:"));
    }

    #[test]
    fn trace_rejects_bad_seed() {
        // Errors out before touching the global trace-enabled flag, so
        // this cannot race the telemetry::trace module tests.
        let e = cmd_trace(&args(&["trace", "--seed", "xyzzy"])).unwrap_err();
        assert!(format!("{e:#}").contains("--seed"));
    }

    #[test]
    fn serve_telemetry_interval_parses_or_errors() {
        // Interval is parsed by cmd_serve, not serve_options; options
        // themselves stay valid.
        assert!(serve_options(&args(&["serve", "--telemetry-interval-ms", "soon"])).is_ok());
        let flag = "telemetry-interval-ms";
        let bad = args(&["serve", "--telemetry-interval-ms", "soon"]);
        assert!(parse_count(&bad, flag).is_err());
        let good = args(&["serve", "--telemetry-interval-ms", "250"]);
        assert_eq!(parse_count(&good, flag).unwrap(), Some(250));
    }

    #[test]
    fn metrics_formats_render() {
        // `--no-demo` keeps the test cheap; each format must succeed.
        assert!(run(args(&["metrics", "--no-demo"])).is_ok());
        assert!(run(args(&["metrics", "--no-demo", "--format", "json"])).is_ok());
        assert!(run(args(&["metrics", "--no-demo", "--format", "prom"])).is_ok());
        assert!(run(args(&["metrics", "--no-demo", "--format", "xml"])).is_err());
    }
}
