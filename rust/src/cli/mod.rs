//! The `abws` command-line interface.
//!
//! ```text
//! abws predict [--net all|resnet32|resnet18|alexnet] [--chunk 64] [--mp 5]
//! abws vrr --macc 12 --n 4096 [--mp 5] [--chunk 64] [--nzr 0.5]
//! abws area
//! abws mc [--n 16384] [--maccs 5,6,8] [--trials 256] [--chunk 64]
//! abws train [--mode native|aot] [--macc 12 | --pp -1] [--chunk 64]
//!            [--steps 300] [--dim 256] [--hidden 64] [--seed 42]
//! abws list
//! abws info
//! ```

use anyhow::{bail, Result};

use crate::coordinator::registry;
use crate::data::synth::{generate, SynthSpec};
use crate::hw::fpu::{FpuAreaModel, FpuConfig};
use crate::hw::report;
use crate::mc::validate;
use crate::nets::nzr::NzrModel;
use crate::nets::predict::predict_network;
use crate::nets::{alexnet, resnet};
use crate::trainer::native::{NativeTrainer, PrecisionPlan, TrainConfig};
use crate::util::argparse::Args;
use crate::vrr;

/// Entry point used by `main.rs`; returns the process exit code.
pub fn run(args: Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("predict") => cmd_predict(&args),
        Some("vrr") => cmd_vrr(&args),
        Some("area") => cmd_area(),
        Some("mc") => cmd_mc(&args),
        Some("train") => cmd_train(&args),
        Some("list") => {
            print!("{}", registry::render_catalog());
            Ok(())
        }
        Some("info") => cmd_info(),
        Some(other) => bail!("unknown command '{other}'\n{}", USAGE),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "usage: abws <predict|vrr|area|mc|train|list|info> [options]
  predict  — Table 1: per-layer-group accumulation precision predictions
  vrr      — evaluate VRR / v(n) for one accumulation setup
  area     — Fig 1b: FPU area model ladder
  mc       — Monte-Carlo validation of the VRR formulas
  train    — reduced-precision training run (native bit-accurate or AOT/PJRT)
  list     — catalog of reproducible experiments
  info     — PJRT runtime info";

fn networks_for(name: &str) -> Result<Vec<(crate::nets::Network, NzrModel)>> {
    Ok(match name {
        "resnet32" => vec![(resnet::resnet32_cifar10(), NzrModel::resnet_default())],
        "resnet18" => vec![(resnet::resnet18_imagenet(), NzrModel::resnet_default())],
        "alexnet" => vec![(alexnet::alexnet_imagenet(), NzrModel::alexnet_default())],
        "all" => vec![
            (resnet::resnet32_cifar10(), NzrModel::resnet_default()),
            (resnet::resnet18_imagenet(), NzrModel::resnet_default()),
            (alexnet::alexnet_imagenet(), NzrModel::alexnet_default()),
        ],
        other => bail!("unknown network '{other}' (resnet32|resnet18|alexnet|all)"),
    })
}

fn cmd_predict(args: &Args) -> Result<()> {
    let m_p = args.get_u32("mp", 5);
    let chunk = args.get_usize("chunk", 64);
    for (net, nzr) in networks_for(args.get_or("net", "all"))? {
        let pred = predict_network(&net, &nzr, m_p, chunk);
        println!("{}", pred.render());
        if args.flag("detail") {
            for lp in &pred.layers {
                println!(
                    "  {:<12} {:<12} fwd n={:<8} bwd n={:<8} grad n={:<8}",
                    lp.layer, lp.group, lp.lengths.fwd, lp.lengths.bwd, lp.lengths.grad
                );
            }
        }
    }
    Ok(())
}

fn cmd_vrr(args: &Args) -> Result<()> {
    let m_acc = args.get_u32("macc", 12);
    let m_p = args.get_u32("mp", 5);
    let n = args.get_usize("n", 4096);
    let nzr = args.get_f64("nzr", 1.0);
    let spec = crate::vrr::solver::AccumSpec {
        n,
        m_p,
        nzr,
        chunk: args.get("chunk").map(|c| c.parse().unwrap()),
    };
    let v = spec.vrr(m_acc);
    let log_v = vrr::variance_lost::log_variance_lost(v, spec.n_eff());
    println!("VRR(m_acc={m_acc}, m_p={m_p}, n={n}, nzr={nzr}, chunk={:?}) = {v:.6}", spec.chunk);
    println!("log v(n) = {log_v:.3} (cutoff ln 50 = {:.3})", vrr::CUTOFF_LN);
    println!(
        "suitable: {}; minimum m_acc for this accumulation: {}",
        spec.suitable(m_acc),
        vrr::solver::min_m_acc(&spec)
    );
    Ok(())
}

fn cmd_area() -> Result<()> {
    let model = FpuAreaModel::default();
    let rows = report::area_rows(&model, &FpuAreaModel::fig1b_configs());
    print!("{}", report::render(&rows));
    let fp8_32 = model.area(&FpuConfig::new(
        crate::softfloat::FpFormat::FP8_152,
        crate::softfloat::FpFormat::FP32,
    ));
    let fp8_16 = model.area(&FpuConfig::new(
        crate::softfloat::FpFormat::FP8_152,
        crate::softfloat::FpFormat::new(6, 9),
    ));
    println!(
        "narrow-accumulator gain (FP8/32 -> FP8/16): {:.2}x",
        fp8_32 / fp8_16
    );
    Ok(())
}

fn cmd_mc(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 16_384);
    let maccs = args.get_u32_list("maccs", &[5, 6, 8, 10]);
    let trials = args.get_usize("trials", 256);
    let chunk = args.get("chunk").map(|c| c.parse().unwrap());
    let seed = args.get_i64("seed", 0x5eed) as u64;
    let pts = validate::validate_grid(&maccs, &[n], chunk, trials, seed);
    print!("{}", validate::render(&pts));
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let dim = args.get_usize("dim", 256);
    let steps = args.get_usize("steps", 300);
    let chunk = args.get("chunk").map(|c| c.parse().unwrap());
    let classes = 10;
    let spec = SynthSpec {
        dim,
        classes,
        seed: args.get_i64("data-seed", 1234) as u64,
        ..Default::default()
    };

    let cfg = TrainConfig {
        hidden: args.get_usize("hidden", 64),
        steps,
        batch: args.get_usize("batch", 32),
        seed: args.get_i64("seed", 42) as u64,
        ..Default::default()
    };

    // Precision plan: explicit --macc, or the solver's prediction (+ --pp).
    let plan = if let Some(m) = args.get("macc") {
        PrecisionPlan::uniform(m.parse()?, chunk)
    } else {
        let pp = args.get_i64("pp", 0) as i32;
        let spec_fwd = crate::vrr::solver::AccumSpec {
            n: dim,
            m_p: 5,
            nzr: 1.0,
            chunk,
        };
        let spec_bwd = crate::vrr::solver::AccumSpec {
            n: classes,
            m_p: 5,
            nzr: 0.5,
            chunk,
        };
        let spec_grad = crate::vrr::solver::AccumSpec {
            n: cfg.batch,
            m_p: 5,
            nzr: 0.5,
            chunk,
        };
        let plan = PrecisionPlan::per_gemm(
            crate::vrr::solver::perturbed(crate::vrr::solver::min_m_acc(&spec_fwd), pp),
            crate::vrr::solver::perturbed(crate::vrr::solver::min_m_acc(&spec_bwd), pp),
            crate::vrr::solver::perturbed(crate::vrr::solver::min_m_acc(&spec_grad), pp),
            chunk,
        );
        println!(
            "predicted m_acc (pp={pp}): fwd={} bwd={} grad={}",
            plan.fwd.acc.man_bits, plan.bwd.acc.man_bits, plan.grad.acc.man_bits
        );
        plan
    };

    match args.get_or("mode", "native") {
        "native" => {
            let (train, test) = generate(&spec);
            let mut t = NativeTrainer::new(dim, classes, plan, cfg);
            let m = t.train(&train);
            let test_acc = t.evaluate(&test);
            report_run(&m, test_acc, steps);
        }
        "aot" => {
            let store =
                crate::runtime::ArtifactStore::open(args.get_or("artifacts", "artifacts"))?;
            store.verify()?;
            let rt = crate::runtime::Runtime::cpu()?;
            let variant = args.get_or("variant", "baseline").to_string();
            let mut exec =
                crate::runtime::TrainStepExecutor::new(&rt, &store, &variant, cfg.seed)?;
            let d = exec.dims;
            let (train, test) = generate(&SynthSpec {
                dim: d.dim,
                classes: d.classes,
                ..spec
            });
            let m = exec.train(&train, steps)?;
            // Evaluate with the native forward on the trained params.
            let (w1, w2) = exec.params()?;
            let mut nt = NativeTrainer::new(d.dim, d.classes, PrecisionPlan::baseline(), cfg);
            nt.w1 = w1;
            nt.w2 = w2;
            let test_acc = nt.evaluate(&test);
            report_run(&m, test_acc, steps);
        }
        other => bail!("unknown mode '{other}' (native|aot)"),
    }
    Ok(())
}

fn report_run(m: &crate::trainer::RunMetrics, test_acc: f64, steps: usize) {
    for r in m.steps.iter().step_by((steps / 20).max(1)) {
        println!(
            "step {:>5}  loss {:>9.4}  train-acc {:>6.3}",
            r.step, r.loss, r.train_acc
        );
    }
    if let Some(r) = m.steps.last() {
        println!(
            "final     loss {:>9.4}  train-acc {:>6.3}",
            r.loss, r.train_acc
        );
    }
    println!("test-acc {test_acc:.4}  diverged: {}", m.diverged);
}

fn cmd_info() -> Result<()> {
    let rt = crate::runtime::Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    Ok(())
}
