//! Experiment configuration: a flat typed key-value config with file
//! loading (JSON), CLI overrides (`--set key=value`) and defaults —
//! the offline stand-in for a serde-based config system.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::Json;

/// A flat configuration map with typed getters.
#[derive(Clone, Debug, Default)]
pub struct ExperimentConfig {
    values: BTreeMap<String, Json>,
}

impl ExperimentConfig {
    pub fn new() -> Self {
        Self::default()
    }

    /// Load from a JSON file of scalars.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let j = Json::parse(&text).context("parsing config JSON")?;
        let Json::Obj(map) = j else {
            bail!("config root must be an object");
        };
        Ok(ExperimentConfig {
            values: map.into_iter().collect(),
        })
    }

    /// Apply a `key=value` override (numbers and bools are auto-typed).
    pub fn set_kv(&mut self, kv: &str) -> Result<()> {
        let Some((k, v)) = kv.split_once('=') else {
            bail!("override '{kv}' is not key=value");
        };
        let val = if let Ok(n) = v.parse::<f64>() {
            Json::Num(n)
        } else if v == "true" || v == "false" {
            Json::Bool(v == "true")
        } else {
            Json::Str(v.to_string())
        };
        self.values.insert(k.to_string(), val);
        Ok(())
    }

    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        self.values.insert(key.to_string(), val.into());
        self
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.values
            .get(key)
            .and_then(Json::as_f64)
            .unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get_f64(key, default as f64) as usize
    }

    pub fn get_u32(&self, key: &str, default: u32) -> u32 {
        self.get_f64(key, default as f64) as u32
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.values
            .get(key)
            .and_then(Json::as_bool)
            .unwrap_or(default)
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.values
            .get(key)
            .and_then(Json::as_str)
            .unwrap_or(default)
            .to_string()
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(self.values.clone().into_iter().collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overrides_and_types() {
        let mut c = ExperimentConfig::new();
        c.set_kv("steps=300").unwrap();
        c.set_kv("lr=0.05").unwrap();
        c.set_kv("chunked=true").unwrap();
        c.set_kv("net=resnet18").unwrap();
        assert_eq!(c.get_usize("steps", 0), 300);
        assert_eq!(c.get_f64("lr", 0.0), 0.05);
        assert!(c.get_bool("chunked", false));
        assert_eq!(c.get_str("net", ""), "resnet18");
        assert_eq!(c.get_usize("missing", 7), 7);
        assert!(c.set_kv("malformed").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("abws_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        let mut c = ExperimentConfig::new();
        c.set("alpha", 1.5).set("name", "x");
        std::fs::write(&path, c.to_json().to_string()).unwrap();
        let back = ExperimentConfig::from_file(&path).unwrap();
        assert_eq!(back.get_f64("alpha", 0.0), 1.5);
        assert_eq!(back.get_str("name", ""), "x");
    }
}
