//! Experiment results and sinks: every harness produces an
//! [`ExperimentResult`] (id + config + rows of named scalars) that can be
//! rendered as a table, CSV, or JSON and written under `results/`.

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::Json;

/// One row of an experiment's output table (ordered key → value).
pub type Row = BTreeMap<String, Json>;

/// The output of one experiment run.
#[derive(Clone, Debug)]
pub struct ExperimentResult {
    /// Experiment id, e.g. `"table1"`, `"fig5a"`.
    pub id: String,
    pub config: Json,
    pub rows: Vec<Row>,
    /// Free-form notes (e.g. paper-vs-measured commentary).
    pub notes: Vec<String>,
}

impl ExperimentResult {
    pub fn new(id: &str) -> ExperimentResult {
        ExperimentResult {
            id: id.to_string(),
            config: Json::obj(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn push_row(&mut self, pairs: &[(&str, Json)]) {
        let mut row = Row::new();
        for (k, v) in pairs {
            row.insert(k.to_string(), v.clone());
        }
        self.rows.push(row);
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("id", self.id.as_str());
        j.set("config", self.config.clone());
        j.set(
            "rows",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| Json::Obj(r.clone().into_iter().collect()))
                    .collect(),
            ),
        );
        j.set(
            "notes",
            Json::Arr(self.notes.iter().map(|n| Json::from(n.as_str())).collect()),
        );
        j
    }

    /// CSV with the union of row keys as header.
    pub fn to_csv(&self) -> String {
        let mut keys: Vec<String> = Vec::new();
        for r in &self.rows {
            for k in r.keys() {
                if !keys.contains(k) {
                    keys.push(k.clone());
                }
            }
        }
        let mut out = keys.join(",");
        out.push('\n');
        for r in &self.rows {
            let cells: Vec<String> = keys
                .iter()
                .map(|k| match r.get(k) {
                    Some(Json::Str(s)) => s.clone(),
                    Some(v) => v.to_string(),
                    None => String::new(),
                })
                .collect();
            out.push_str(&cells.join(","));
            out.push('\n');
        }
        out
    }
}

/// Writes results under a directory as both JSON and CSV.
pub struct ResultSink {
    pub dir: std::path::PathBuf,
}

impl ResultSink {
    pub fn new(dir: impl AsRef<Path>) -> Result<ResultSink> {
        std::fs::create_dir_all(dir.as_ref())
            .with_context(|| format!("creating {}", dir.as_ref().display()))?;
        Ok(ResultSink {
            dir: dir.as_ref().to_path_buf(),
        })
    }

    pub fn write(&self, result: &ExperimentResult) -> Result<()> {
        std::fs::write(
            self.dir.join(format!("{}.json", result.id)),
            result.to_json().to_string(),
        )?;
        std::fs::write(
            self.dir.join(format!("{}.csv", result.id)),
            result.to_csv(),
        )?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_csv_json() {
        let mut r = ExperimentResult::new("t");
        r.push_row(&[("n", Json::from(64.0)), ("vrr", Json::from(0.99))]);
        r.push_row(&[("n", Json::from(128.0)), ("vrr", Json::from(0.95))]);
        r.note("hello");
        let csv = r.to_csv();
        assert!(csv.starts_with("n,vrr"));
        assert!(csv.contains("128,0.95"));
        let j = r.to_json();
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn sink_writes_files() {
        let dir = std::env::temp_dir().join("abws_sink_test");
        let sink = ResultSink::new(&dir).unwrap();
        let mut r = ExperimentResult::new("unit");
        r.push_row(&[("x", Json::from(1.0))]);
        sink.write(&r).unwrap();
        assert!(dir.join("unit.json").exists());
        assert!(dir.join("unit.csv").exists());
    }

    #[test]
    fn csv_handles_ragged_rows() {
        let mut r = ExperimentResult::new("t");
        r.push_row(&[("a", Json::from(1.0))]);
        r.push_row(&[("b", Json::from(2.0))]);
        let csv = r.to_csv();
        assert!(csv.starts_with("a,b"));
        assert!(csv.contains("1,\n") || csv.contains("1,"));
    }
}
