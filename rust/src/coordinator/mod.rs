//! Experiment coordination: typed configs, a registry of named
//! experiments (one per paper table/figure), a thread-pooled sweep
//! runner, and JSON/CSV result sinks. The `cargo bench` targets and the
//! CLI are thin drivers over this module.

pub mod config;
pub mod experiment;
pub mod registry;
pub mod sweep;

pub use config::ExperimentConfig;
pub use experiment::{ExperimentResult, ResultSink};
pub use sweep::{run_sweep, SweepPoint};
