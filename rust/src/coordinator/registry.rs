//! Catalog of the paper's experiments: stable ids, descriptions, and the
//! command that regenerates each (DESIGN.md §3's per-experiment index,
//! machine-readable).

/// Static descriptor of one reproducible experiment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExperimentInfo {
    pub id: &'static str,
    pub paper_artifact: &'static str,
    pub description: &'static str,
    pub command: &'static str,
}

/// All experiments, in paper order.
pub const EXPERIMENTS: &[ExperimentInfo] = &[
    ExperimentInfo {
        id: "table1",
        paper_artifact: "Table 1",
        description: "Predicted (normal, chunked) accumulation mantissa widths per layer group and GEMM for ResNet-32/CIFAR-10, ResNet-18/ImageNet, AlexNet/ImageNet",
        command: "cargo bench --bench table1 (or: abws predict --net all)",
    },
    ExperimentInfo {
        id: "fig1a",
        paper_artifact: "Figure 1(a)",
        description: "Divergence of training when the accumulation precision is reduced naively (scaled-down bit-accurate run)",
        command: "cargo bench --bench fig1a_divergence",
    },
    ExperimentInfo {
        id: "fig1b",
        paper_artifact: "Figure 1(b)",
        description: "Estimated FPU area vs multiplier/accumulator precision; the extra 1.5-2.2x from narrow accumulation",
        command: "cargo bench --bench fig1b_area (or: abws area)",
    },
    ExperimentInfo {
        id: "fig3",
        paper_artifact: "Figure 3",
        description: "Weight-gradient variance vs layer index: baseline vs reduced-precision GRAD accumulation",
        command: "cargo bench --bench fig3_variance",
    },
    ExperimentInfo {
        id: "fig5a",
        paper_artifact: "Figure 5(a)",
        description: "Normalized variance lost v(n) vs accumulation length, no chunking, m_acc sweep",
        command: "cargo bench --bench fig5_vrr (or: abws vrr --sweep)",
    },
    ExperimentInfo {
        id: "fig5b",
        paper_artifact: "Figure 5(b)",
        description: "v(n) vs accumulation length with chunk-64 accumulation",
        command: "cargo bench --bench fig5_vrr",
    },
    ExperimentInfo {
        id: "fig5c",
        paper_artifact: "Figure 5(c)",
        description: "VRR vs chunk size for several accumulation setups (flat maxima)",
        command: "cargo bench --bench fig5_vrr",
    },
    ExperimentInfo {
        id: "fig6",
        paper_artifact: "Figure 6(a-c)",
        description: "Convergence curves at the predicted precision and under precision perturbation (PP), normal and chunked",
        command: "cargo bench --bench fig6_convergence (or: abws train)",
    },
    ExperimentInfo {
        id: "fig6d",
        paper_artifact: "Figure 6(d)",
        description: "Final accuracy degradation vs precision perturbation",
        command: "cargo bench --bench fig6_convergence",
    },
    ExperimentInfo {
        id: "mc",
        paper_artifact: "(validation)",
        description: "Monte-Carlo empirical VRR vs Theorem 1/Corollary 1 over an (m_acc, n) grid",
        command: "abws mc",
    },
];

/// Look up an experiment by id.
pub fn find(id: &str) -> Option<&'static ExperimentInfo> {
    EXPERIMENTS.iter().find(|e| e.id == id)
}

/// Render the catalog as a text table.
pub fn render_catalog() -> String {
    let mut out = String::new();
    for e in EXPERIMENTS {
        out.push_str(&format!(
            "{:<8} {:<14} {}\n{:<8} {:<14} -> {}\n",
            e.id, e.paper_artifact, e.description, "", "", e.command
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_table_and_figure() {
        // The paper's evaluation artifacts: Table 1, Fig 1a/1b, Fig 3,
        // Fig 5a/5b/5c, Fig 6a-c/6d.
        for id in [
            "table1", "fig1a", "fig1b", "fig3", "fig5a", "fig5b", "fig5c", "fig6", "fig6d",
        ] {
            assert!(find(id).is_some(), "missing experiment {id}");
        }
    }

    #[test]
    fn ids_unique() {
        let mut ids: Vec<&str> = EXPERIMENTS.iter().map(|e| e.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), EXPERIMENTS.len());
    }

    #[test]
    fn catalog_renders() {
        let text = render_catalog();
        assert!(text.contains("Table 1"));
        assert!(text.contains("cargo bench"));
    }
}
