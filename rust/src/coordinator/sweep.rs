//! Thread-pooled parameter sweeps: run a closure over a grid of points
//! with bounded parallelism (std::thread::scope — no rayon offline) while
//! preserving input order in the output.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::thread;

/// One point of a sweep with its index in the grid.
#[derive(Clone, Debug)]
pub struct SweepPoint<P> {
    pub index: usize,
    pub params: P,
}

/// The default worker count for pooled work: one per available core
/// (1 if the parallelism query fails). Shared by [`run_sweep`] and the
/// `api::serve` worker pool.
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f` over `points` with up to `threads` workers (0 = one per
/// available core); results come back in input order. Panics in workers
/// are propagated.
pub fn run_sweep<P, R, F>(points: Vec<P>, threads: usize, f: F) -> Vec<R>
where
    P: Send + Sync,
    R: Send,
    F: Fn(&P) -> R + Sync,
{
    let n = points.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = if threads == 0 { default_threads() } else { threads };
    let threads = threads.clamp(1, n);
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let points_ref = &points;
    let f_ref = &f;
    let next_ref = &next;
    let slots_ref = &slots;

    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(move || loop {
                let i = next_ref.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f_ref(&points_ref[i]);
                *slots_ref[i].lock().unwrap() = Some(r);
            });
        }
    });

    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker skipped a point"))
        .collect()
}

/// Cartesian product of two parameter lists.
pub fn grid2<A: Clone, B: Clone>(a: &[A], b: &[B]) -> Vec<(A, B)> {
    let mut out = Vec::with_capacity(a.len() * b.len());
    for x in a {
        for y in b {
            out.push((x.clone(), y.clone()));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let points: Vec<usize> = (0..100).collect();
        let out = run_sweep(points, 8, |&p| p * 2);
        assert_eq!(out, (0..100).map(|p| p * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_and_empty() {
        assert_eq!(run_sweep(vec![1, 2, 3], 1, |&p| p + 1), vec![2, 3, 4]);
        let empty: Vec<i32> = run_sweep(Vec::<i32>::new(), 4, |&p| p);
        assert!(empty.is_empty());
    }

    #[test]
    fn more_threads_than_points() {
        assert_eq!(run_sweep(vec![5], 64, |&p| p), vec![5]);
    }

    #[test]
    fn zero_threads_means_default() {
        assert!(default_threads() >= 1);
        assert_eq!(run_sweep(vec![1, 2, 3], 0, |&p| p + 1), vec![2, 3, 4]);
    }

    #[test]
    fn grid_product() {
        let g = grid2(&[1, 2], &["a", "b", "c"]);
        assert_eq!(g.len(), 6);
        assert_eq!(g[0], (1, "a"));
        assert_eq!(g[5], (2, "c"));
    }

    #[test]
    fn actually_parallel() {
        // All workers must participate: with 4 threads and sleeping work,
        // wall time should be well under serial time.
        use std::time::{Duration, Instant};
        let t = Instant::now();
        let _ = run_sweep((0..8).collect::<Vec<_>>(), 4, |_| {
            thread::sleep(Duration::from_millis(30))
        });
        let elapsed = t.elapsed();
        assert!(
            elapsed < Duration::from_millis(8 * 30),
            "elapsed {elapsed:?}"
        );
    }
}
