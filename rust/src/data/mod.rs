//! Synthetic datasets (see DESIGN.md §5: ImageNet/CIFAR are not available
//! in this environment; the VRR theory depends on operand statistics, not
//! image content).

pub mod synth;

pub use synth::{Dataset, SynthSpec};
