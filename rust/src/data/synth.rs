//! Class-conditional Gaussian-mixture classification data.
//!
//! Each class `c` gets a random unit-ish mean vector `μ_c`; samples are
//! `x = μ_c + σ·z`, `z ~ N(0, I)`. The task difficulty is controlled by
//! the noise-to-separation ratio, chosen so a small MLP reaches high
//! accuracy in a few hundred steps at full precision — giving reduced-
//! precision degradation room to show (paper Fig. 6's 0.5% band).

use crate::softfloat::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Specification of a synthetic classification dataset.
#[derive(Clone, Copy, Debug)]
pub struct SynthSpec {
    pub n_train: usize,
    pub n_test: usize,
    /// Input dimensionality (the FWD accumulation length of layer 1).
    pub dim: usize,
    pub classes: usize,
    /// Within-class noise σ (means have norm ≈ 1).
    pub noise: f64,
    pub seed: u64,
}

impl Default for SynthSpec {
    fn default() -> Self {
        SynthSpec {
            n_train: 2048,
            n_test: 512,
            dim: 256,
            classes: 10,
            noise: 1.0,
            seed: 1234,
        }
    }
}

/// An in-memory dataset of feature rows and integer labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// `[n, dim]`.
    pub x: Tensor,
    pub y: Vec<usize>,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Copy out mini-batch `idx` of size `bs` (wraps around).
    pub fn batch(&self, step: usize, bs: usize) -> (Tensor, Vec<usize>) {
        let n = self.len();
        let dim = self.x.shape[1];
        let mut xb = Tensor::zeros(&[bs, dim]);
        let mut yb = Vec::with_capacity(bs);
        for i in 0..bs {
            let j = (step * bs + i) % n;
            xb.data[i * dim..(i + 1) * dim]
                .copy_from_slice(&self.x.data[j * dim..(j + 1) * dim]);
            yb.push(self.y[j]);
        }
        (xb, yb)
    }
}

/// Generate a `(train, test)` pair from a spec.
pub fn generate(spec: &SynthSpec) -> (Dataset, Dataset) {
    let mut rng = Pcg64::seeded(spec.seed);
    // Class means: random Gaussian directions, normalized to unit norm.
    let mut means = vec![vec![0.0f64; spec.dim]; spec.classes];
    for m in means.iter_mut() {
        let mut norm = 0.0;
        for v in m.iter_mut() {
            *v = rng.normal();
            norm += *v * *v;
        }
        let norm = norm.sqrt().max(1e-9);
        for v in m.iter_mut() {
            *v /= norm;
        }
    }

    let make = |count: usize, rng: &mut Pcg64| -> Dataset {
        let mut x = Tensor::zeros(&[count, spec.dim]);
        let mut y = Vec::with_capacity(count);
        for i in 0..count {
            let c = rng.next_below(spec.classes as u64) as usize;
            y.push(c);
            for d in 0..spec.dim {
                // Scale by 1/sqrt(dim) so feature variance ~ O(1/dim) and
                // dot products stay O(1) — matching He-init statistics.
                let v = means[c][d] + spec.noise * rng.normal() / (spec.dim as f64).sqrt();
                x.data[i * spec.dim + d] = v as f32;
            }
        }
        Dataset {
            x,
            y,
            classes: spec.classes,
        }
    };

    let train = make(spec.n_train, &mut rng);
    let test = make(spec.n_test, &mut rng);
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let spec = SynthSpec {
            n_train: 100,
            n_test: 40,
            dim: 32,
            classes: 4,
            ..Default::default()
        };
        let (tr, te) = generate(&spec);
        assert_eq!(tr.x.shape, vec![100, 32]);
        assert_eq!(te.len(), 40);
        assert!(tr.y.iter().all(|&c| c < 4));
        // All classes appear.
        for c in 0..4 {
            assert!(tr.y.iter().any(|&y| y == c));
        }
    }

    #[test]
    fn batches_wrap_around() {
        let spec = SynthSpec {
            n_train: 10,
            n_test: 4,
            dim: 8,
            classes: 2,
            ..Default::default()
        };
        let (tr, _) = generate(&spec);
        let (xb, yb) = tr.batch(3, 4); // indices 12..16 → wrap to 2..6
        assert_eq!(xb.shape, vec![4, 8]);
        assert_eq!(yb.len(), 4);
        assert_eq!(yb[0], tr.y[12 % 10]);
    }

    #[test]
    fn deterministic() {
        let spec = SynthSpec::default();
        let (a, _) = generate(&spec);
        let (b, _) = generate(&spec);
        assert_eq!(a.x.data, b.x.data);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn classes_are_separable() {
        // Nearest-mean classification on the training set should beat
        // chance by a wide margin — otherwise the trainer can't converge.
        let spec = SynthSpec {
            n_train: 400,
            n_test: 0,
            dim: 64,
            classes: 4,
            noise: 1.0,
            seed: 7,
        };
        let (tr, _) = generate(&spec);
        // Estimate class means from data.
        let mut means = vec![vec![0.0f64; spec.dim]; spec.classes];
        let mut counts = vec![0usize; spec.classes];
        for i in 0..tr.len() {
            let c = tr.y[i];
            counts[c] += 1;
            for d in 0..spec.dim {
                means[c][d] += tr.x.data[i * spec.dim + d] as f64;
            }
        }
        for c in 0..spec.classes {
            for d in 0..spec.dim {
                means[c][d] /= counts[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..tr.len() {
            let mut best = (f64::INFINITY, 0);
            for c in 0..spec.classes {
                let d2: f64 = (0..spec.dim)
                    .map(|d| {
                        let diff = tr.x.data[i * spec.dim + d] as f64 - means[c][d];
                        diff * diff
                    })
                    .sum();
                if d2 < best.0 {
                    best = (d2, c);
                }
            }
            if best.1 == tr.y[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / tr.len() as f64;
        assert!(acc > 0.8, "nearest-mean accuracy {acc}");
    }
}
