//! Parametric FPU area model (paper Fig. 1b).
//!
//! The paper's figure comes from "a model underpinned by the hardware
//! synthesis of low-precision floating-point units". We reproduce the
//! model's structure with synthesis-inspired component scaling:
//!
//! * mantissa multiplier array — quadratic in the multiplier's mantissa
//!   width `(m+1)²` (partial-product array);
//! * alignment shifter + normalizer of the adder — `(m+1)·log₂(m+1)`
//!   (barrel shifter depth × width) on the *accumulator* mantissa;
//! * significand adder + rounding — linear in the accumulator mantissa;
//! * exponent datapath — linear in the exponent widths;
//! * fixed control overhead.
//!
//! Constants are calibrated so the well-known synthesis ratios hold
//! (FP16 FPU ≈ ⅓–½ of FP32; see tests) and so the paper's headline claim
//! — an extra 1.5–2.2× from narrowing the accumulator of an FP8
//! multiplier — falls out (Fig. 1b).

use crate::softfloat::FpFormat;

/// An `FPa/b` unit in the paper's notation: a multiplier operating on
/// `mult` inputs and an adder/accumulator operating at `acc`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FpuConfig {
    pub mult: FpFormat,
    pub acc: FpFormat,
}

impl FpuConfig {
    pub fn new(mult: FpFormat, acc: FpFormat) -> Self {
        FpuConfig { mult, acc }
    }

    /// Paper naming: `FP<mult-bits>/<acc-bits>`.
    pub fn name(&self) -> String {
        format!("FP{}/{}", self.mult.bits(), self.acc.bits())
    }
}

/// The area model with its component coefficients (arbitrary gate-area
/// units; only ratios are meaningful, as in the paper's figure).
#[derive(Clone, Copy, Debug)]
pub struct FpuAreaModel {
    /// Multiplier array coefficient (per mantissa-bit²).
    pub c_mul: f64,
    /// Shifter coefficient (per bit·log-bit of the accumulator).
    pub c_shift: f64,
    /// Adder/round coefficient (per accumulator mantissa bit).
    pub c_add: f64,
    /// Exponent-path coefficient (per exponent bit).
    pub c_exp: f64,
    /// Fixed control overhead.
    pub c_fixed: f64,
}

impl Default for FpuAreaModel {
    fn default() -> Self {
        // Calibrated against public synthesis ratios — see module docs.
        FpuAreaModel {
            c_mul: 1.0,
            c_shift: 2.0,
            c_add: 4.0,
            c_exp: 6.0,
            c_fixed: 10.0,
        }
    }
}

impl FpuAreaModel {
    /// Absolute area (arbitrary units) of an FPU configuration.
    pub fn area(&self, cfg: &FpuConfig) -> f64 {
        let mm = (cfg.mult.man_bits + 1) as f64; // incl. hidden bit
        let ma = (cfg.acc.man_bits + 1) as f64;
        self.c_mul * mm * mm
            + self.c_shift * ma * ma.log2().max(1.0)
            + self.c_add * ma
            + self.c_exp * (cfg.mult.exp_bits + cfg.acc.exp_bits) as f64
            + self.c_fixed
    }

    /// Area normalized to the FP32/32 baseline (the y-axis of Fig. 1b).
    pub fn relative_area(&self, cfg: &FpuConfig) -> f64 {
        self.area(cfg) / self.area(&FpuConfig::new(FpFormat::FP32, FpFormat::FP32))
    }

    /// The Fig. 1b ladder of configurations, most to least precise.
    pub fn fig1b_configs() -> Vec<FpuConfig> {
        let fp16_acc = FpFormat::new(6, 9); // the paper's 16-b accumulator (1,6,9)
        vec![
            FpuConfig::new(FpFormat::FP32, FpFormat::FP32),
            FpuConfig::new(FpFormat::FP16, FpFormat::FP32),
            FpuConfig::new(FpFormat::FP8_152, FpFormat::FP32),
            FpuConfig::new(FpFormat::FP16, fp16_acc),
            FpuConfig::new(FpFormat::FP8_152, fp16_acc),
            FpuConfig::new(FpFormat::FP8_152, FpFormat::new(6, 5)), // ~12-b acc
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> FpuAreaModel {
        FpuAreaModel::default()
    }

    #[test]
    fn fp32_baseline_is_one() {
        let m = model();
        let base = FpuConfig::new(FpFormat::FP32, FpFormat::FP32);
        assert!((m.relative_area(&base) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn area_monotone_in_each_knob() {
        let m = model();
        // Narrower multiplier shrinks area, all else equal.
        let wide = m.area(&FpuConfig::new(FpFormat::FP16, FpFormat::FP32));
        let narrow = m.area(&FpuConfig::new(FpFormat::FP8_152, FpFormat::FP32));
        assert!(narrow < wide);
        // Narrower accumulator shrinks area, all else equal.
        let acc_wide = m.area(&FpuConfig::new(FpFormat::FP8_152, FpFormat::FP32));
        let acc_narrow = m.area(&FpuConfig::new(FpFormat::FP8_152, FpFormat::new(6, 9)));
        assert!(acc_narrow < acc_wide);
    }

    #[test]
    fn fp16_fpu_is_third_to_half_of_fp32() {
        // Public synthesis results put a full FP16 FPU at ~25–50% of FP32.
        let m = model();
        let r = m.relative_area(&FpuConfig::new(FpFormat::FP16, FpFormat::FP16));
        assert!((0.2..=0.5).contains(&r), "r={r}");
    }

    #[test]
    fn paper_headline_accumulator_gain() {
        // Fig. 1b's message: with an FP8 multiplier, narrowing the
        // accumulator from 32-b to 16-b/12-b buys an extra 1.5–2.2×.
        let m = model();
        let fp8_32 = m.area(&FpuConfig::new(FpFormat::FP8_152, FpFormat::FP32));
        let fp8_16 = m.area(&FpuConfig::new(FpFormat::FP8_152, FpFormat::new(6, 9)));
        let fp8_12 = m.area(&FpuConfig::new(FpFormat::FP8_152, FpFormat::new(6, 5)));
        let gain16 = fp8_32 / fp8_16;
        let gain12 = fp8_32 / fp8_12;
        assert!((1.5..=2.2).contains(&gain16), "gain16={gain16}");
        assert!(gain12 >= gain16, "gain12={gain12} < gain16={gain16}");
        assert!(gain12 <= 3.0, "gain12={gain12}");
    }

    #[test]
    fn high_precision_accumulation_limits_benefits() {
        // The paper's motivation: with a 32-b accumulator, dropping the
        // multiplier from FP16 to FP8 saves little (accumulator dominates).
        let m = model();
        let fp16_32 = m.area(&FpuConfig::new(FpFormat::FP16, FpFormat::FP32));
        let fp8_32 = m.area(&FpuConfig::new(FpFormat::FP8_152, FpFormat::FP32));
        let gain = fp16_32 / fp8_32;
        assert!(gain < 1.5, "multiplier-only gain should be limited: {gain}");
    }

    #[test]
    fn config_names() {
        assert_eq!(
            FpuConfig::new(FpFormat::FP8_152, FpFormat::FP32).name(),
            "FP8/32"
        );
        assert_eq!(
            FpuConfig::new(FpFormat::FP16, FpFormat::new(6, 9)).name(),
            "FP16/16"
        );
    }

    #[test]
    fn fig1b_ladder_is_decreasing() {
        let m = model();
        let areas: Vec<f64> = FpuAreaModel::fig1b_configs()
            .iter()
            .map(|c| m.relative_area(c))
            .collect();
        for w in areas.windows(2) {
            assert!(w[1] < w[0] + 1e-12, "{areas:?}");
        }
    }
}
