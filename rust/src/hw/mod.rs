//! Hardware cost models: the floating-point-unit area model behind the
//! paper's Figure 1(b) ("estimated area benefits when reducing the
//! precision of a floating-point unit").

pub mod fpu;
pub mod report;

pub use fpu::{FpuConfig, FpuAreaModel};
