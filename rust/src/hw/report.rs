//! Rendering of the Fig. 1b area comparison as a text table/bar chart.

use super::fpu::{FpuAreaModel, FpuConfig};

/// One row of the Fig. 1b report.
#[derive(Clone, Debug)]
pub struct AreaRow {
    pub name: String,
    pub area: f64,
    pub relative: f64,
    /// Reduction factor vs the FP32/32 baseline.
    pub reduction: f64,
}

/// Compute the Fig. 1b rows for a set of configurations.
pub fn area_rows(model: &FpuAreaModel, configs: &[FpuConfig]) -> Vec<AreaRow> {
    configs
        .iter()
        .map(|c| {
            let rel = model.relative_area(c);
            AreaRow {
                name: c.name(),
                area: model.area(c),
                relative: rel,
                reduction: 1.0 / rel,
            }
        })
        .collect()
}

/// ASCII bar chart of relative areas (the shape of Fig. 1b).
pub fn render(rows: &[AreaRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<10} {:>10} {:>10} {:>8}  chart\n",
        "FPU", "area", "rel", "gain"
    ));
    for r in rows {
        let bar = "#".repeat((r.relative * 50.0).round().max(1.0) as usize);
        out.push_str(&format!(
            "{:<10} {:>10.1} {:>10.3} {:>7.2}x  {}\n",
            r.name, r.area, r.relative, r.reduction, bar
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_and_render() {
        let model = FpuAreaModel::default();
        let rows = area_rows(&model, &FpuAreaModel::fig1b_configs());
        assert_eq!(rows.len(), 6);
        assert!((rows[0].relative - 1.0).abs() < 1e-12);
        assert!((rows[0].reduction - 1.0).abs() < 1e-12);
        let text = render(&rows);
        assert!(text.contains("FP32/32"));
        assert!(text.contains("FP8/16"));
        // Bars shrink monotonically down the ladder.
        assert!(rows.last().unwrap().reduction > 3.0);
    }
}
