//! # abws — Accumulation Bit-Width Scaling
//!
//! Reproduction of *"Accumulation Bit-Width Scaling For Ultra-Low
//! Precision Training Of Deep Networks"* (Sakr et al., ICLR 2019), grown
//! into a precision-advisory service: feed in layer shapes, get back the
//! minimum accumulator mantissa widths — "without computationally
//! prohibitive brute-force emulations".
//!
//! ## The `api` layer
//!
//! [`api`] is the single typed entry point to the stack. A
//! [`api::PrecisionPolicy`] carries the whole precision configuration
//! (representation/product/accumulator formats, chunking, rounding,
//! sparsity); typed requests go in, typed reports come out, and every
//! solve is memoized behind [`api::cache`]:
//!
//! ```no_run
//! use abws::api::{AdvisorRequest, PrecisionPolicy};
//!
//! let policy = PrecisionPolicy::paper().with_chunk(Some(64));
//! let report = AdvisorRequest::builtin("resnet18", policy).run().unwrap();
//! println!("{}", report.render()); // the paper's Table-1 row
//! ```
//!
//! Batch traffic goes through `abws serve` ([`api::serve`]), which maps
//! newline-delimited JSON requests to newline-delimited JSON reports:
//!
//! ```text
//! $ abws serve <<'EOF'
//! {"type":"advisor","network":"resnet32","policy":{"chunk":64}}
//! {"type":"advisor","network":{"name":"mine","batch":256,"layers":[
//!    {"kind":"conv","c_in":3,"c_out":64,"kernel":7,"h_out":112},
//!    {"kind":"fc","c_in":2048,"c_out":1000}]}}
//! {"type":"train","plan":{"kind":"predicted","pp":-1},"steps":100}
//! EOF
//! {"chunk":64,...,"network":"CIFAR-10 ResNet-32","type":"advisor_report"}
//! {"chunk":64,...,"network":"mine","type":"advisor_report"}
//! {"diverged":false,...,"type":"train_report"}
//! ```
//!
//! Every report line answers the request on the same input line; bad
//! requests produce `{"error": ...}` lines without stopping the stream.
//!
//! ## The analysis stack underneath
//!
//! * **Layer 3 (this crate)** — the variance-retention-ratio (VRR)
//!   theory ([`vrr`]), a bit-accurate reduced-precision floating-point
//!   simulator ([`softfloat`]), network topology models ([`nets`]), the
//!   FPU area model ([`hw`]), Monte-Carlo validation ([`mc`]), a
//!   pure-Rust reduced-precision trainer ([`trainer`]) and the
//!   experiment coordinator ([`coordinator`]).
//! * **Layer 2 (python/compile/model.py)** — a JAX model whose forward
//!   and backward GEMMs use the reduced-precision accumulation kernel,
//!   lowered once to HLO text artifacts.
//! * **Layer 1 (python/compile/kernels/)** — the Pallas kernel
//!   implementing chunked reduced-precision accumulation, verified
//!   against a pure-jnp oracle.
//!
//! The [`runtime`] module loads the AOT artifacts and executes them on
//! the PJRT CPU client (cargo feature `pjrt`; without it the runtime is
//! reduced to artifact discovery and the rest of the crate is fully
//! self-contained).
//!
//! ## Observability
//!
//! The whole stack is instrumented through [`telemetry`], a
//! zero-dependency metrics registry (counters, gauges, log2-bucketed
//! latency histograms) wired through the solver, solve cache,
//! Monte-Carlo ensembles and `abws serve`. Inspect it with the
//! `abws metrics` subcommand, `abws serve --telemetry`, or
//! [`telemetry::snapshot`] in code; see `docs/telemetry.md` for the
//! metrics catalog.

pub mod api;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod hw;
pub mod mc;
pub mod nets;
pub mod runtime;
pub mod softfloat;
pub mod telemetry;
pub mod trainer;
pub mod util;
pub mod vrr;
