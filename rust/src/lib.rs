//! # abws — Accumulation Bit-Width Scaling
//!
//! Reproduction of *"Accumulation Bit-Width Scaling For Ultra-Low Precision
//! Training Of Deep Networks"* (Sakr et al., ICLR 2019).
//!
//! The crate is organised as a three-layer stack:
//!
//! * **Layer 3 (this crate)** — the analysis + coordination layer: the
//!   variance-retention-ratio (VRR) theory ([`vrr`]), a bit-accurate
//!   reduced-precision floating-point simulator ([`softfloat`]), network
//!   topology models ([`nets`]), the FPU area model ([`hw`]), Monte-Carlo
//!   validation ([`mc`]), a pure-Rust reduced-precision trainer
//!   ([`trainer`]) and the experiment coordinator ([`coordinator`]).
//! * **Layer 2 (python/compile/model.py)** — a JAX model whose forward and
//!   backward GEMMs use the reduced-precision accumulation kernel, lowered
//!   once to HLO text artifacts.
//! * **Layer 1 (python/compile/kernels/)** — the Pallas kernel implementing
//!   chunked reduced-precision accumulation, verified against a pure-jnp
//!   oracle.
//!
//! The [`runtime`] module loads the AOT artifacts and executes them on the
//! PJRT CPU client; Python is never on the run path.

pub mod cli;
pub mod coordinator;
pub mod data;
pub mod hw;
pub mod mc;
pub mod nets;
pub mod runtime;
pub mod softfloat;
pub mod trainer;
pub mod util;
pub mod vrr;
