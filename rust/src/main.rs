//! `abws` — Accumulation Bit-Width Scaling: CLI entry point.

use abws::cli;
use abws::util::argparse::Args;

fn main() {
    let args = Args::from_env();
    if let Err(e) = cli::run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
