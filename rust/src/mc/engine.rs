//! Sweep-vectorized Monte-Carlo VRR engine on the shared worker pool.
//!
//! The paper's analysis is a *sweep*: Fig. 5 re-measures the VRR at every
//! candidate `(m_acc, chunk)` point, and every caller of
//! [`super::sim::empirical_vrr`] (the `abws vrr --empirical` and `abws mc`
//! sweeps, the fig3/fig5 benches, serve `test` requests) loops that same
//! experiment over a grid. Per point, the expensive part is not the
//! reduced-precision accumulation — it is *drawing* the ensemble: one
//! Box–Muller normal plus one product quantization per term. This engine
//! evaluates the whole grid against the **same drawn terms**: one RNG +
//! product-quantize pass per trial, amortized across every sweep point,
//! with each configuration's accumulation running through a sum kernel
//! resolved once per config (monomorphized per `(RoundMode, chunked)`,
//! identity fast path included — the same once-per-panel resolution the
//! GEMM kernel does).
//!
//! Trials run on the persistent [`crate::runtime::pool`] instead of
//! spawning `thread::scope` workers per call; each pool participant keeps
//! one terms buffer alive across all the trials it claims.
//!
//! ## Determinism argument
//!
//! The result is bit-identical to the retained single-config oracle
//! [`super::sim::empirical_vrr_ref`] at **any** thread count:
//!
//! 1. Trial `i` always draws from PCG stream `i + 1` of `seed`, so the
//!    terms of a trial do not depend on which participant runs it.
//! 2. Participants write each trial's `(reduced…, exact)` samples into
//!    that trial's disjoint slot of one preallocated buffer — no shared
//!    accumulator is touched inside the parallel region.
//! 3. The streaming [`Welford`] moments are computed *after* the join, on
//!    the caller, by pushing samples in global trial order. (Welford
//!    `merge` is not bitwise-equivalent to sequential `push`, so per
//!    worker partial moments would break bit-identity; buffering samples
//!    per trial makes any work partition safe.)
//!
//! The work split itself (an atomic trial index) can vary freely between
//! runs — nothing downstream observes it.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::runtime::pool;
use crate::softfloat::accumulate::{chunked_sum_q, exact_sum, sequential_sum_q};
use crate::softfloat::format::FpFormat;
use crate::softfloat::quant::{Quantizer, Rne, RoundMode, Rounding, Rtz};
use crate::telemetry::{self, trace, Timer};
use crate::util::rng::Pcg64;
use crate::util::stats::Welford;

use super::sim::McResult;

/// The shared half of a sweep: everything that determines the *drawn
/// ensemble* (terms and trial structure), independent of how the terms
/// are then accumulated.
#[derive(Clone, Copy, Debug)]
pub struct Ensemble {
    /// Accumulation length.
    pub n: usize,
    /// Product mantissa bits (products are drawn pre-rounded to this).
    pub m_p: u32,
    /// Exponent bits of the accumulator formats (paper: 6).
    pub e_acc: u32,
    /// Product standard deviation σ_p.
    pub sigma_p: f64,
    /// Number of independent accumulations in the ensemble.
    pub trials: usize,
    pub seed: u64,
    /// Pool participants (the caller plus `threads - 1` pool workers).
    pub threads: usize,
}

/// One sweep point: how the shared terms are accumulated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AccumSetup {
    /// Accumulator mantissa bits.
    pub m_acc: u32,
    /// Chunk size (`None` = plain sequential accumulation).
    pub chunk: Option<usize>,
    /// Rounding mode of the accumulation.
    pub rounding: Rounding,
}

impl AccumSetup {
    pub fn new(m_acc: u32) -> AccumSetup {
        AccumSetup {
            m_acc,
            chunk: None,
            rounding: Rounding::NearestEven,
        }
    }

    pub fn with_chunk(mut self, chunk: usize) -> AccumSetup {
        self.chunk = Some(chunk);
        self
    }

    pub fn with_rounding(mut self, rounding: Rounding) -> AccumSetup {
        self.rounding = rounding;
        self
    }
}

/// Structured rejection of a degenerate Monte-Carlo request. The old
/// `empirical_vrr` silently divided 0/0 on `trials < 2` and returned a
/// NaN VRR; the engine refuses instead, and `api::serve` surfaces these
/// as the unified `{"error":{...}}` shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum McError {
    /// An ensemble variance needs at least two trials.
    TooFewTrials(usize),
    /// A length-zero accumulation has no variance to retain.
    EmptyAccumulation,
    /// A sweep point asked for chunk size zero.
    ZeroChunk,
    /// The sweep grid is empty.
    EmptyGrid,
}

impl fmt::Display for McError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            McError::TooFewTrials(t) => write!(
                f,
                "Monte-Carlo ensemble needs at least 2 trials to estimate a variance, got {t}"
            ),
            McError::EmptyAccumulation => {
                write!(f, "zero-length accumulation (n must be >= 1)")
            }
            McError::ZeroChunk => write!(f, "chunk size must be at least 1"),
            McError::EmptyGrid => write!(f, "sweep grid must contain at least one setup"),
        }
    }
}

impl std::error::Error for McError {}

/// One grid config's accumulation path, resolved once before the
/// parallel region: the accumulator [`Quantizer`] (format constants
/// precomputed), the chunk size, and a function pointer to the sum
/// routine monomorphized for `(RoundMode, chunked)` — with the
/// `man_bits >= 52` identity case dispatched to plain-f64 sums here, not
/// per element (the once-per-panel resolution the GEMM kernel does).
struct SumKernel {
    q: Quantizer,
    chunk: usize,
    run: fn(&[f64], usize, &Quantizer) -> f64,
}

fn seq_kern<R: RoundMode>(terms: &[f64], _chunk: usize, q: &Quantizer) -> f64 {
    sequential_sum_q::<R>(terms, q)
}

fn chunk_kern<R: RoundMode>(terms: &[f64], chunk: usize, q: &Quantizer) -> f64 {
    chunked_sum_q::<R>(terms, chunk, q)
}

fn ident_seq_kern(terms: &[f64], _chunk: usize, _q: &Quantizer) -> f64 {
    let mut s = 0.0;
    for &p in terms {
        s += p;
    }
    s
}

fn ident_chunk_kern(terms: &[f64], chunk: usize, _q: &Quantizer) -> f64 {
    let mut inter = 0.0;
    for block in terms.chunks(chunk) {
        let mut intra = 0.0;
        for &p in block {
            intra += p;
        }
        inter += intra;
    }
    inter
}

impl SumKernel {
    fn resolve(e_acc: u32, setup: &AccumSetup) -> SumKernel {
        let q = Quantizer::new(FpFormat::new(e_acc, setup.m_acc), setup.rounding);
        let (chunk, run): (usize, fn(&[f64], usize, &Quantizer) -> f64) =
            match (setup.chunk, setup.rounding, q.is_identity()) {
                (None, _, true) => (0, ident_seq_kern),
                (Some(c), _, true) => (c, ident_chunk_kern),
                (None, Rounding::NearestEven, false) => (0, seq_kern::<Rne>),
                (None, Rounding::TowardZero, false) => (0, seq_kern::<Rtz>),
                (Some(c), Rounding::NearestEven, false) => (c, chunk_kern::<Rne>),
                (Some(c), Rounding::TowardZero, false) => (c, chunk_kern::<Rtz>),
            };
        SumKernel { q, chunk, run }
    }

    #[inline]
    fn sum(&self, terms: &[f64]) -> f64 {
        (self.run)(terms, self.chunk, &self.q)
    }
}

/// Raw base pointer into the sample buffer, shareable across pool
/// participants. Safety rests on the trial-claim protocol: each trial
/// index is handed out exactly once by the atomic counter, and a
/// participant only writes the `stride` slots of trials it claimed.
struct SendPtr(*mut f64);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Measure the VRR of every [`AccumSetup`] in `grid` against one shared
/// drawn ensemble, in one pass over the trials.
///
/// Returns one [`McResult`] per grid entry, in grid order. Each entry is
/// bit-identical to running [`super::sim::empirical_vrr_ref`] on that
/// single configuration (same `n`, `trials`, `seed`, …), at any
/// `threads` value — see the module docs for the determinism argument.
pub fn sweep_vrr(ens: &Ensemble, grid: &[AccumSetup]) -> Result<Vec<McResult>, McError> {
    if ens.trials < 2 {
        return Err(McError::TooFewTrials(ens.trials));
    }
    if ens.n == 0 {
        return Err(McError::EmptyAccumulation);
    }
    if grid.is_empty() {
        return Err(McError::EmptyGrid);
    }
    if grid.iter().any(|s| s.chunk == Some(0)) {
        return Err(McError::ZeroChunk);
    }

    let run_timer = telemetry::enabled().then(Timer::start);
    // Parent span for the sweep; pool regions (and the per-trial spans
    // inside them) attach below it.
    let _sspan = if trace::enabled() {
        trace::TraceSpan::enter("mc.sweep")
            .attr("trials", ens.trials.to_string())
            .attr("n", ens.n.to_string())
            .attr("width", grid.len().to_string())
    } else {
        trace::TraceSpan::noop()
    };
    // All per-config constants resolved once, outside the trial loop.
    let kernels: Vec<SumKernel> = grid
        .iter()
        .map(|s| SumKernel::resolve(ens.e_acc, s))
        .collect();
    let prod_q = Quantizer::new(FpFormat::new(6, ens.m_p), Rounding::NearestEven);

    let width = grid.len();
    let stride = width + 1; // per trial: one reduced sum per config + the exact sum
    let trials = ens.trials;
    let mut samples = vec![0.0f64; trials * stride];
    let out = SendPtr(samples.as_mut_ptr());
    let next = AtomicUsize::new(0);
    let threads = ens.threads.clamp(1, trials);

    let report = pool::run(threads, &|| {
        // One terms buffer per participant, reused across every trial it
        // claims — the trial loop allocates nothing.
        let mut terms = vec![0.0f64; ens.n];
        loop {
            let trial = next.fetch_add(1, Ordering::Relaxed);
            if trial >= trials {
                break;
            }
            let _tspan = if trace::enabled() {
                trace::TraceSpan::enter("mc.trial").attr("trial", trial.to_string())
            } else {
                trace::TraceSpan::noop()
            };
            // One PCG stream per trial: trial `i` draws the same terms
            // whichever participant runs it.
            let mut rng = Pcg64::new(ens.seed, trial as u64 + 1);
            for p in terms.iter_mut() {
                *p = prod_q.quantize_m::<Rne>(rng.normal() * ens.sigma_p);
            }
            // Safety: `trial` was claimed exactly once above, so this
            // `stride`-slot row is written by this participant only, and
            // the buffer outlives the region (pool::run joins before
            // returning).
            let row = unsafe { std::slice::from_raw_parts_mut(out.0.add(trial * stride), stride) };
            for (slot, kern) in row.iter_mut().zip(&kernels) {
                *slot = kern.sum(&terms);
            }
            row[width] = exact_sum(&terms);
        }
    });

    // Ensemble moments: sequential Welford pushes in global trial order
    // (bit-identity contract — see the module docs; `Welford::merge`
    // would not preserve it).
    let mut reduced: Vec<Welford> = (0..width).map(|_| Welford::new()).collect();
    let mut ideal = Welford::new();
    for row in samples.chunks_exact(stride) {
        for (w, &v) in reduced.iter_mut().zip(row.iter()) {
            w.push(v);
        }
        ideal.push(row[width]);
    }

    if let Some(timer) = run_timer {
        telemetry::counter("abws_mc_runs_total").inc();
        telemetry::counter("abws_mc_trials_total").add(trials as u64);
        telemetry::histogram("abws_mc_run_wall_ns").record(timer.elapsed_ns());
        telemetry::histogram("abws_mc_engine_sweep_width").record(width as u64);
        let terms_per_sec =
            ((trials * ens.n) as u64).saturating_mul(1_000_000_000) / report.wall_ns.max(1);
        telemetry::histogram("abws_mc_engine_terms_per_sec").record(terms_per_sec);
        let util = telemetry::histogram("abws_mc_engine_worker_utilization_pct");
        for pct in report.utilization_pct() {
            util.record(pct);
        }
    }

    let var_ideal = ideal.variance();
    Ok(reduced
        .into_iter()
        .map(|w| {
            let var_swamping = w.variance();
            McResult {
                var_swamping,
                var_ideal,
                vrr: var_swamping / var_ideal,
                trials,
            }
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ens(n: usize, trials: usize, threads: usize) -> Ensemble {
        Ensemble {
            n,
            m_p: 5,
            e_acc: 6,
            sigma_p: 1.0,
            trials,
            seed: 0x5eed,
            threads,
        }
    }

    #[test]
    fn degenerate_requests_are_rejected() {
        let grid = [AccumSetup::new(8)];
        assert_eq!(
            sweep_vrr(&ens(64, 1, 1), &grid),
            Err(McError::TooFewTrials(1))
        );
        assert_eq!(
            sweep_vrr(&ens(64, 0, 1), &grid),
            Err(McError::TooFewTrials(0))
        );
        assert_eq!(
            sweep_vrr(&ens(0, 16, 1), &grid),
            Err(McError::EmptyAccumulation)
        );
        assert_eq!(sweep_vrr(&ens(64, 16, 1), &[]), Err(McError::EmptyGrid));
        assert_eq!(
            sweep_vrr(&ens(64, 16, 1), &[AccumSetup::new(8).with_chunk(0)]),
            Err(McError::ZeroChunk)
        );
        let msg = McError::TooFewTrials(1).to_string();
        assert!(msg.contains("at least 2"), "{msg}");
    }

    #[test]
    fn sweep_results_come_back_in_grid_order() {
        let grid = [
            AccumSetup::new(4),
            AccumSetup::new(20),
            AccumSetup::new(4).with_chunk(64),
        ];
        let r = sweep_vrr(&ens(4096, 64, 2), &grid).unwrap();
        assert_eq!(r.len(), 3);
        // Wider accumulator retains more; chunking rescues the narrow one.
        assert!(r[1].vrr > r[0].vrr);
        assert!(r[2].vrr > r[0].vrr);
        // The exact-sum ensemble is shared across the grid.
        assert_eq!(r[0].var_ideal.to_bits(), r[1].var_ideal.to_bits());
        assert_eq!(r[0].var_ideal.to_bits(), r[2].var_ideal.to_bits());
        assert!(r.iter().all(|x| x.trials == 64));
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        // 33 trials across 4 participants exercises an uneven split.
        let grid = [
            AccumSetup::new(7),
            AccumSetup::new(7).with_chunk(16),
            AccumSetup::new(9).with_rounding(Rounding::TowardZero),
        ];
        let base = sweep_vrr(&ens(1024, 33, 1), &grid).unwrap();
        for threads in [2usize, 4, 8] {
            let got = sweep_vrr(&ens(1024, 33, threads), &grid).unwrap();
            for (a, b) in base.iter().zip(&got) {
                assert_eq!(a.vrr.to_bits(), b.vrr.to_bits(), "threads={threads}");
                assert_eq!(a.var_swamping.to_bits(), b.var_swamping.to_bits());
                assert_eq!(a.var_ideal.to_bits(), b.var_ideal.to_bits());
            }
        }
    }

    #[test]
    fn identity_width_retains_everything() {
        // m_acc = 52 resolves to the identity fast-path kernel.
        let r = sweep_vrr(&ens(2048, 32, 2), &[AccumSetup::new(52)]).unwrap();
        assert!((r[0].vrr - 1.0).abs() < 1e-9, "vrr={}", r[0].vrr);
    }
}
