//! Monte-Carlo validation of the VRR theory against the bit-accurate
//! simulator: generate ensembles of reduced-precision accumulations,
//! measure the empirical variance retention, and compare with Theorem 1 /
//! Corollary 1.
//!
//! The hot path is the sweep-vectorized [`engine`] (see `docs/mc.md`);
//! [`empirical_vrr`] is a one-config wrapper over it, and
//! [`empirical_vrr_ref`] retains the original scoped-thread
//! implementation as the bit-identity oracle.

pub mod engine;
pub mod sim;
pub mod validate;

pub use engine::{sweep_vrr, AccumSetup, Ensemble, McError};
pub use sim::{empirical_vrr, empirical_vrr_ref, McConfig, McResult};
pub use validate::{validate_grid, GridPoint};
