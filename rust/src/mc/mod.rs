//! Monte-Carlo validation of the VRR theory against the bit-accurate
//! simulator: generate ensembles of reduced-precision accumulations,
//! measure the empirical variance retention, and compare with Theorem 1 /
//! Corollary 1.

pub mod sim;
pub mod validate;

pub use sim::{empirical_vrr, McConfig, McResult};
pub use validate::{validate_grid, GridPoint};
