//! Monte-Carlo measurement of the variance retention ratio.
//!
//! For each trial we draw `n` iid product terms `p_i = rnd_{m_p}(σ_p·Z)`,
//! `Z ~ N(0,1)` (Assumption 1), run the reduced-precision accumulation,
//! and compare the ensemble second moment of the reduced-precision result
//! against the ensemble second moment of the exact sum of the *same*
//! samples (paired design — removes most sampling noise from the ratio).
//!
//! [`empirical_vrr`] is a thin one-config wrapper around the
//! sweep-vectorized [`super::engine`]; the original `thread::scope`
//! implementation is retained as [`empirical_vrr_ref`], the oracle the
//! engine's bit-identity suite (`tests/mc_engine.rs`) and the
//! `perf_hotpath` result-hash check compare against.

use std::thread;

use crate::softfloat::accumulate::{chunked_sum_ref, exact_sum, sequential_sum_ref};
use crate::softfloat::format::FpFormat;
use crate::softfloat::quant::{Quantizer, Rounding};
use crate::telemetry::{self, Timer};
use crate::util::rng::Pcg64;
use crate::util::stats::Welford;

use super::engine::{self, AccumSetup, Ensemble, McError};

/// Monte-Carlo experiment configuration.
#[derive(Clone, Copy, Debug)]
pub struct McConfig {
    /// Accumulation length.
    pub n: usize,
    /// Accumulator mantissa bits.
    pub m_acc: u32,
    /// Product mantissa bits (products are drawn pre-rounded to this).
    pub m_p: u32,
    /// Exponent bits of the accumulator (paper: 6).
    pub e_acc: u32,
    /// Chunk size (`None` = plain sequential accumulation).
    pub chunk: Option<usize>,
    /// Rounding mode of the accumulation (products are always drawn
    /// round-to-nearest-even, per Assumption 1).
    pub rounding: Rounding,
    /// Number of independent accumulations in the ensemble.
    pub trials: usize,
    /// Product standard deviation σ_p.
    pub sigma_p: f64,
    pub seed: u64,
    /// Worker threads.
    pub threads: usize,
}

impl McConfig {
    pub fn new(n: usize, m_acc: u32) -> McConfig {
        McConfig {
            n,
            m_acc,
            m_p: 5,
            e_acc: 6,
            chunk: None,
            rounding: Rounding::NearestEven,
            trials: 256,
            sigma_p: 1.0,
            seed: 0x5eed,
            threads: crate::coordinator::sweep::default_threads(),
        }
    }

    pub fn with_chunk(mut self, chunk: usize) -> McConfig {
        self.chunk = Some(chunk);
        self
    }

    pub fn with_rounding(mut self, rounding: Rounding) -> McConfig {
        self.rounding = rounding;
        self
    }

    pub fn with_trials(mut self, trials: usize) -> McConfig {
        self.trials = trials;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> McConfig {
        self.seed = seed;
        self
    }

    /// The shared-ensemble half of this config (what determines the
    /// drawn terms), for the sweep engine.
    pub fn ensemble(&self) -> Ensemble {
        Ensemble {
            n: self.n,
            m_p: self.m_p,
            e_acc: self.e_acc,
            sigma_p: self.sigma_p,
            trials: self.trials,
            seed: self.seed,
            threads: self.threads,
        }
    }

    /// The accumulation half of this config (one engine sweep point).
    pub fn setup(&self) -> AccumSetup {
        AccumSetup {
            m_acc: self.m_acc,
            chunk: self.chunk,
            rounding: self.rounding,
        }
    }
}

/// Monte-Carlo outcome.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct McResult {
    /// Empirical `Var(s_n)` of the reduced-precision ensemble.
    pub var_swamping: f64,
    /// Empirical `Var(s_n)` of the exact-sum ensemble (same samples).
    pub var_ideal: f64,
    /// `var_swamping / var_ideal` — the measured VRR.
    pub vrr: f64,
    pub trials: usize,
}

/// Run the Monte-Carlo experiment for one configuration.
///
/// A thin wrapper over [`engine::sweep_vrr`] with a single-point grid:
/// trials run on the persistent worker pool, and degenerate requests
/// (`trials < 2`, `n == 0`, zero chunk) are rejected with a structured
/// [`McError`] instead of silently returning a NaN VRR.
///
/// **Deterministic in everything but `threads`, including `threads`** —
/// bit-identical to [`empirical_vrr_ref`] at any thread count (see
/// `mc::engine`'s module docs for the argument).
pub fn empirical_vrr(cfg: &McConfig) -> Result<McResult, McError> {
    let mut results = engine::sweep_vrr(&cfg.ensemble(), &[cfg.setup()])?;
    Ok(results.pop().expect("one result per grid point"))
}

/// The retained reference implementation of [`empirical_vrr`]: scoped
/// threads spawned per call, free-`quantize` `*_ref` accumulation, and
/// no degenerate-request guard (`trials < 2` reproduces the historical
/// NaN). This is the oracle the engine must match bit-for-bit; it is not
/// a hot path.
pub fn empirical_vrr_ref(cfg: &McConfig) -> McResult {
    let worker_tput =
        telemetry::enabled().then(|| telemetry::histogram("abws_mc_worker_trials_per_sec"));
    let acc_fmt = FpFormat::new(cfg.e_acc, cfg.m_acc);
    let prod_fmt = FpFormat::new(6, cfg.m_p);
    // Product-format constants hoisted out of the trial loop; bit-identical
    // to the free `quantize` this replaced.
    let prod_q = Quantizer::new(prod_fmt, Rounding::NearestEven);
    let threads = cfg.threads.max(1).min(cfg.trials.max(1));
    let per = cfg.trials.div_ceil(threads);

    let chunks: Vec<Vec<(f64, f64)>> = thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..threads {
            let first = t * per;
            let count = per.min(cfg.trials.saturating_sub(first));
            if count == 0 {
                break;
            }
            let tput = worker_tput.clone();
            handles.push(scope.spawn(move || {
                let timer = tput.is_some().then(Timer::start);
                let mut samples = Vec::with_capacity(count);
                let mut terms = vec![0.0f64; cfg.n];
                for trial in first..first + count {
                    // One PCG stream per trial: trial `i` draws the same
                    // terms whichever worker runs it.
                    let mut rng = Pcg64::new(cfg.seed, trial as u64 + 1);
                    for p in terms.iter_mut() {
                        *p = prod_q.quantize(rng.normal() * cfg.sigma_p);
                    }
                    let reduced = match cfg.chunk {
                        Some(c) => chunked_sum_ref(&terms, c, acc_fmt, cfg.rounding),
                        None => sequential_sum_ref(&terms, acc_fmt, cfg.rounding),
                    };
                    samples.push((reduced, exact_sum(&terms)));
                }
                if let (Some(h), Some(timer)) = (&tput, timer) {
                    let ns = timer.elapsed_ns().max(1);
                    h.record((count as u64).saturating_mul(1_000_000_000) / ns);
                }
                samples
            }));
        }
        // Spawn order == trial order, so concatenation restores the
        // global trial sequence.
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let (mut sw, mut id) = (Welford::new(), Welford::new());
    for (reduced, exact) in chunks.into_iter().flatten() {
        sw.push(reduced);
        id.push(exact);
    }
    let var_swamping = sw.variance();
    let var_ideal = id.variance();
    McResult {
        var_swamping,
        var_ideal,
        vrr: var_swamping / var_ideal,
        trials: sw.count() as usize,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wide_accumulator_retains_everything() {
        let r = empirical_vrr(&McConfig::new(4_096, 20).with_trials(128)).unwrap();
        assert!((r.vrr - 1.0).abs() < 0.05, "vrr={}", r.vrr);
        assert_eq!(r.trials, 128);
    }

    #[test]
    fn narrow_accumulator_loses_variance() {
        let r = empirical_vrr(&McConfig::new(16_384, 5).with_trials(128)).unwrap();
        assert!(r.vrr < 0.7, "vrr={}", r.vrr);
    }

    #[test]
    fn ideal_variance_scales_linearly_in_n() {
        // Var(s_n) ≈ n·σ_p² under ideal accumulation (Assumption 1).
        let r1 = empirical_vrr(&McConfig::new(1_024, 20).with_trials(256)).unwrap();
        let r4 = empirical_vrr(&McConfig::new(4_096, 20).with_trials(256)).unwrap();
        let ratio = r4.var_ideal / r1.var_ideal;
        assert!((ratio - 4.0).abs() < 1.0, "ratio={ratio}");
    }

    #[test]
    fn chunking_recovers_variance() {
        let base = McConfig::new(16_384, 5).with_trials(128);
        let plain = empirical_vrr(&base).unwrap();
        let chunked = empirical_vrr(&base.with_chunk(64)).unwrap();
        assert!(
            chunked.vrr > plain.vrr + 0.1,
            "chunked {} vs plain {}",
            chunked.vrr,
            plain.vrr
        );
    }

    #[test]
    fn deterministic_given_seed_and_threads() {
        let mut cfg = McConfig::new(2_048, 8).with_trials(64).with_seed(7);
        cfg.threads = 3;
        let a = empirical_vrr(&cfg).unwrap();
        let b = empirical_vrr(&cfg).unwrap();
        assert_eq!(a.vrr, b.vrr);
    }

    /// Per-trial PCG streams make the estimate independent of the worker
    /// split — `threads=1` and `threads=4` must agree to the last bit
    /// (33 trials also exercises an uneven split), and the engine-backed
    /// wrapper must agree with the retained scoped-thread oracle.
    #[test]
    fn bit_identical_across_thread_counts_and_to_the_oracle() {
        let base = McConfig::new(1_024, 7).with_trials(33).with_seed(42);
        let want = empirical_vrr_ref(&base);
        let mut results = Vec::new();
        for threads in [1usize, 2, 4] {
            let mut cfg = base;
            cfg.threads = threads;
            results.push(empirical_vrr(&cfg).unwrap());
        }
        for r in &results {
            assert_eq!(r.vrr.to_bits(), want.vrr.to_bits());
            assert_eq!(r.var_swamping.to_bits(), want.var_swamping.to_bits());
            assert_eq!(r.var_ideal.to_bits(), want.var_ideal.to_bits());
            assert_eq!(r.trials, 33);
        }
    }

    #[test]
    fn trial_split_is_exact() {
        let mut cfg = McConfig::new(128, 10).with_trials(97);
        cfg.threads = 8; // 97 not divisible by 8
        let r = empirical_vrr(&cfg).unwrap();
        assert_eq!(r.trials, 97);
    }

    #[test]
    fn degenerate_ensemble_is_an_error_not_a_nan() {
        let e = empirical_vrr(&McConfig::new(64, 8).with_trials(1)).unwrap_err();
        assert_eq!(e, McError::TooFewTrials(1));
        let e = empirical_vrr(&McConfig::new(0, 8).with_trials(16)).unwrap_err();
        assert_eq!(e, McError::EmptyAccumulation);
        // The oracle keeps the historical behaviour (it *is* the record
        // of what the old path did): one trial → NaN VRR.
        let nan = empirical_vrr_ref(&McConfig::new(64, 8).with_trials(1));
        assert!(nan.vrr.is_nan());
    }

    #[test]
    fn rounding_mode_feeds_through() {
        let base = McConfig::new(8_192, 6).with_trials(96).with_seed(3);
        let rne = empirical_vrr(&base).unwrap();
        let rtz = empirical_vrr(&base.with_rounding(Rounding::TowardZero)).unwrap();
        // Truncation is strictly lossier on average; same drawn terms.
        assert_eq!(rne.var_ideal.to_bits(), rtz.var_ideal.to_bits());
        assert!(rtz.vrr < rne.vrr + 1e-12, "rtz={} rne={}", rtz.vrr, rne.vrr);
    }
}
