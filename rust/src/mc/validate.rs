//! Theory-vs-measurement grid: sweep `(m_acc, n)` and report the
//! closed-form VRR (Theorem 1 / Corollary 1) next to the Monte-Carlo
//! measurement. This is the repository's strongest evidence that both the
//! formula implementation *and* the bit-accurate simulator are right —
//! they were built independently and meet in the middle.
//!
//! Measurements run through the sweep-vectorized [`super::engine`]: one
//! engine pass per `n` covers every `m_acc` against the same drawn
//! ensemble, instead of re-drawing the terms at every grid point. Because
//! the old per-point loop reused one seed per `n` anyway, the measured
//! values are bit-identical to what the looped `empirical_vrr` produced.

use super::engine::{sweep_vrr, AccumSetup, Ensemble, McError};
use crate::coordinator::sweep::default_threads;
use crate::vrr::chunking::vrr_chunked_total;
use crate::vrr::theorem::vrr;

/// One grid point of the validation sweep.
#[derive(Clone, Copy, Debug)]
pub struct GridPoint {
    pub n: usize,
    pub m_acc: u32,
    pub chunk: Option<usize>,
    pub theory: f64,
    pub measured: f64,
    pub abs_err: f64,
}

/// Sweep a grid of `(m_acc, n)` points, plain or chunked.
///
/// Output stays in `m_acc`-major order (every `n` per `m_acc`), matching
/// the historical loop; internally the sweep is `n`-major so each drawn
/// ensemble is shared across all accumulator widths.
pub fn validate_grid(
    m_accs: &[u32],
    ns: &[usize],
    chunk: Option<usize>,
    trials: usize,
    seed: u64,
) -> Result<Vec<GridPoint>, McError> {
    let grid: Vec<AccumSetup> = m_accs
        .iter()
        .map(|&m_acc| {
            let s = AccumSetup::new(m_acc);
            match chunk {
                Some(c) => s.with_chunk(c),
                None => s,
            }
        })
        .collect();

    // measured[mi][nj]
    let mut measured: Vec<Vec<f64>> = vec![vec![0.0; ns.len()]; m_accs.len()];
    for (nj, &n) in ns.iter().enumerate() {
        let ens = Ensemble {
            n,
            m_p: 5,
            e_acc: 6,
            sigma_p: 1.0,
            trials,
            seed,
            threads: default_threads(),
        };
        for (mi, r) in sweep_vrr(&ens, &grid)?.into_iter().enumerate() {
            measured[mi][nj] = r.vrr;
        }
    }

    let mut out = Vec::with_capacity(m_accs.len() * ns.len());
    for (mi, &m_acc) in m_accs.iter().enumerate() {
        for (nj, &n) in ns.iter().enumerate() {
            let theory = match chunk {
                Some(c) => vrr_chunked_total(m_acc, 5, n, c),
                None => vrr(m_acc, 5, n),
            };
            let measured = measured[mi][nj];
            out.push(GridPoint {
                n,
                m_acc,
                chunk,
                theory,
                measured,
                abs_err: (theory - measured).abs(),
            });
        }
    }
    Ok(out)
}

/// Render the grid as an aligned text table.
pub fn render(points: &[GridPoint]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:>8} {:>6} {:>7} {:>9} {:>9} {:>8}\n",
        "n", "m_acc", "chunk", "theory", "measured", "|err|"
    ));
    for p in points {
        out.push_str(&format!(
            "{:>8} {:>6} {:>7} {:>9.4} {:>9.4} {:>8.4}\n",
            p.n,
            p.m_acc,
            p.chunk.map(|c| c.to_string()).unwrap_or("-".into()),
            p.theory,
            p.measured,
            p.abs_err
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The decisive property: theory and simulation agree on *which side
    /// of the knee* every grid point sits (VRR ≈ 1 vs clearly degraded).
    /// The paper's formula is a typical-case surrogate model, so we
    /// assert knee agreement and coarse numeric closeness, not equality.
    #[test]
    fn theory_and_simulation_agree_on_the_knee() {
        let pts = validate_grid(&[6, 10], &[256, 4_096, 65_536], None, 96, 11).unwrap();
        for p in &pts {
            if p.theory > 0.995 {
                assert!(
                    p.measured > 0.9,
                    "theory says fine but sim lost variance: {p:?}"
                );
            }
            if p.theory < 0.4 {
                assert!(
                    p.measured < 0.85,
                    "theory says collapse but sim retained: {p:?}"
                );
            }
        }
    }

    #[test]
    fn both_monotone_in_m_acc() {
        let pts = validate_grid(&[4, 6, 8, 12], &[8_192], None, 96, 5).unwrap();
        for w in pts.windows(2) {
            assert!(w[1].theory >= w[0].theory - 1e-9);
            // MC noise allowance on the measured side.
            assert!(w[1].measured >= w[0].measured - 0.1, "{pts:?}");
        }
    }

    #[test]
    fn chunked_grid_improves_on_plain() {
        let plain = validate_grid(&[5], &[16_384], None, 96, 3).unwrap();
        let chunked = validate_grid(&[5], &[16_384], Some(64), 96, 3).unwrap();
        assert!(chunked[0].theory > plain[0].theory);
        assert!(chunked[0].measured > plain[0].measured);
    }

    #[test]
    fn render_table_mentions_every_point() {
        let pts = validate_grid(&[8], &[512, 1_024], None, 16, 1).unwrap();
        let text = render(&pts);
        assert!(text.contains("512") && text.contains("1024"));
    }

    #[test]
    fn degenerate_grid_is_an_error() {
        assert_eq!(
            validate_grid(&[8], &[512], None, 1, 1).unwrap_err(),
            McError::TooFewTrials(1)
        );
        assert_eq!(
            validate_grid(&[], &[512], None, 16, 1).unwrap_err(),
            McError::EmptyGrid
        );
    }

    /// The engine sweep must reproduce the per-point loop it replaced:
    /// same seed per `n` → same drawn terms → bitwise-equal measurements.
    #[test]
    fn grid_matches_looped_single_config_runs() {
        use super::super::sim::{empirical_vrr_ref, McConfig};
        let pts = validate_grid(&[5, 9], &[1_024, 2_048], Some(32), 48, 7).unwrap();
        for p in &pts {
            let want = empirical_vrr_ref(
                &McConfig::new(p.n, p.m_acc)
                    .with_chunk(32)
                    .with_trials(48)
                    .with_seed(7),
            );
            assert_eq!(p.measured.to_bits(), want.vrr.to_bits(), "{p:?}");
        }
    }
}
