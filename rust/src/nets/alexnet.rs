//! AlexNet (Krizhevsky et al. 2012) topology for ImageNet, batch 256,
//! with the Table 1 group labels (Conv 1–5, FC 1–2; the final classifier
//! layer is kept at 16-b precision by the paper and excluded here).

use super::layer::{Layer, Network};

/// ImageNet AlexNet, batch 256.
pub fn alexnet_imagenet() -> Network {
    let layers = vec![
        // name, group, c_in, c_out, k, h_out, w_out
        Layer::conv("conv1", "Conv 1", 3, 96, 11, 55, 55),
        Layer::conv("conv2", "Conv 2", 96, 256, 5, 27, 27),
        Layer::conv("conv3", "Conv 3", 256, 384, 3, 13, 13),
        Layer::conv("conv4", "Conv 4", 384, 384, 3, 13, 13),
        Layer::conv("conv5", "Conv 5", 384, 256, 3, 13, 13),
        Layer::fc("fc6", "FC 1", 256 * 6 * 6, 4096),
        Layer::fc("fc7", "FC 2", 4096, 4096),
    ];
    Network {
        name: "ImageNet AlexNet".into(),
        batch: 256,
        layers,
        first_layer: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::lengths::accum_lengths;

    #[test]
    fn seven_table1_rows() {
        let net = alexnet_imagenet();
        assert_eq!(
            net.groups(),
            vec!["Conv 1", "Conv 2", "Conv 3", "Conv 4", "Conv 5", "FC 1", "FC 2"]
        );
    }

    #[test]
    fn conv1_lengths() {
        let net = alexnet_imagenet();
        let l = accum_lengths(&net, &net.layers[0]);
        assert_eq!(l.fwd, 3 * 11 * 11); // 363
        assert_eq!(l.bwd, 96 * 11 * 11);
        assert_eq!(l.grad, 256 * 55 * 55); // 774,400
    }

    #[test]
    fn fc_lengths() {
        let net = alexnet_imagenet();
        let fc6 = accum_lengths(&net, &net.layers[5]);
        assert_eq!(fc6.fwd, 9216);
        assert_eq!(fc6.bwd, 4096);
        assert_eq!(fc6.grad, 256);
    }

    #[test]
    fn param_count_sane() {
        // AlexNet conv+fc6+fc7 ≈ 2.3M + 37.7M + 16.8M ≈ 57M params.
        let p = alexnet_imagenet().total_params();
        assert!((50_000_000..65_000_000).contains(&p), "params={p}");
    }
}
