//! Layer and network descriptions — just the shape information the
//! accumulation-length analysis needs (paper Fig. 2): channel counts,
//! kernel sizes, output spatial dims, and the mini-batch size.

/// Kind of a compute layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// 2-D convolution.
    Conv,
    /// Fully connected (GEMM).
    Fc,
}

/// One weight layer of a network, with everything needed to derive the
/// three GEMM accumulation lengths.
#[derive(Clone, Debug)]
pub struct Layer {
    /// Display name, e.g. `"conv2_1a"` or `"fc6"`.
    pub name: String,
    /// Group label used by Table 1 (e.g. `"ResBlock 1"`, `"Conv 0"`).
    pub group: String,
    pub kind: LayerKind,
    /// Input channels (fan-in channels).
    pub c_in: usize,
    /// Output channels.
    pub c_out: usize,
    /// Square kernel size (1 for FC).
    pub kernel: usize,
    /// Output feature-map height (1 for FC).
    pub h_out: usize,
    /// Output feature-map width (1 for FC).
    pub w_out: usize,
}

impl Layer {
    pub fn conv(
        name: &str,
        group: &str,
        c_in: usize,
        c_out: usize,
        kernel: usize,
        h_out: usize,
        w_out: usize,
    ) -> Layer {
        Layer {
            name: name.into(),
            group: group.into(),
            kind: LayerKind::Conv,
            c_in,
            c_out,
            kernel,
            h_out,
            w_out,
        }
    }

    pub fn fc(name: &str, group: &str, c_in: usize, c_out: usize) -> Layer {
        Layer {
            name: name.into(),
            group: group.into(),
            kind: LayerKind::Fc,
            c_in,
            c_out,
            kernel: 1,
            h_out: 1,
            w_out: 1,
        }
    }

    /// Weight-tensor parameter count.
    pub fn params(&self) -> usize {
        self.c_in * self.c_out * self.kernel * self.kernel
    }
}

/// A whole network: its layers in order plus the training mini-batch size
/// the paper used (GRAD accumulation runs across the batch).
#[derive(Clone, Debug)]
pub struct Network {
    pub name: String,
    pub batch: usize,
    pub layers: Vec<Layer>,
    /// Index of the first layer (no BWD GEMM is needed for it — there is
    /// no upstream activation gradient; Table 1 marks it N/A).
    pub first_layer: usize,
}

impl Network {
    /// Distinct group labels in layer order (Table 1 columns).
    pub fn groups(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for l in &self.layers {
            if out.last().map(|g| g != &l.group).unwrap_or(true) {
                out.push(l.group.clone());
            }
        }
        out
    }

    pub fn total_params(&self) -> usize {
        self.layers.iter().map(|l| l.params()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_params() {
        let l = Layer::conv("c", "g", 64, 128, 3, 28, 28);
        assert_eq!(l.params(), 64 * 128 * 9);
    }

    #[test]
    fn fc_shape_defaults() {
        let l = Layer::fc("fc", "FC 1", 4096, 1000);
        assert_eq!(l.kernel, 1);
        assert_eq!((l.h_out, l.w_out), (1, 1));
        assert_eq!(l.params(), 4_096_000);
    }

    #[test]
    fn groups_dedup_preserves_order() {
        let net = Network {
            name: "t".into(),
            batch: 1,
            first_layer: 0,
            layers: vec![
                Layer::conv("a", "G1", 3, 16, 3, 32, 32),
                Layer::conv("b", "G1", 16, 16, 3, 32, 32),
                Layer::conv("c", "G2", 16, 32, 3, 16, 16),
            ],
        };
        assert_eq!(net.groups(), vec!["G1", "G2"]);
    }
}
