//! Accumulation lengths of the three back-propagation GEMMs (paper
//! Fig. 2). For a conv layer with `C_in` input channels, `C_out` output
//! channels, `k×k` kernels, `H_out×W_out` output maps and mini-batch `B`:
//!
//! * **FWD**  — each output activation accumulates `C_in · k²` products;
//! * **BWD**  — each input-gradient element accumulates `C_out · k²`;
//! * **GRAD** — each weight gradient accumulates `B · H_out · W_out`
//!   (across the batch and every output position).
//!
//! For FC layers the spatial terms collapse to 1.

use super::layer::{Layer, LayerKind, Network};

/// Which of the three GEMMs of one back-prop iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Gemm {
    Fwd,
    Bwd,
    Grad,
}

impl Gemm {
    pub const ALL: [Gemm; 3] = [Gemm::Fwd, Gemm::Bwd, Gemm::Grad];

    pub fn name(&self) -> &'static str {
        match self {
            Gemm::Fwd => "FWD",
            Gemm::Bwd => "BWD",
            Gemm::Grad => "GRAD",
        }
    }

    /// Inverse of [`Gemm::name`] (used by the `api` JSON codecs).
    pub fn from_name(name: &str) -> Option<Gemm> {
        match name {
            "FWD" => Some(Gemm::Fwd),
            "BWD" => Some(Gemm::Bwd),
            "GRAD" => Some(Gemm::Grad),
            _ => None,
        }
    }
}

/// The three accumulation lengths of one layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AccumLengths {
    pub fwd: usize,
    pub bwd: usize,
    pub grad: usize,
}

impl AccumLengths {
    pub fn get(&self, g: Gemm) -> usize {
        match g {
            Gemm::Fwd => self.fwd,
            Gemm::Bwd => self.bwd,
            Gemm::Grad => self.grad,
        }
    }
}

/// Accumulation lengths of `layer` inside `net` (the batch size comes
/// from the network).
pub fn accum_lengths(net: &Network, layer: &Layer) -> AccumLengths {
    match layer.kind {
        LayerKind::Conv => AccumLengths {
            fwd: layer.c_in * layer.kernel * layer.kernel,
            bwd: layer.c_out * layer.kernel * layer.kernel,
            grad: net.batch * layer.h_out * layer.w_out,
        },
        LayerKind::Fc => AccumLengths {
            fwd: layer.c_in,
            bwd: layer.c_out,
            grad: net.batch,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::layer::Layer;

    fn net_with(batch: usize, layer: Layer) -> Network {
        Network {
            name: "t".into(),
            batch,
            first_layer: 0,
            layers: vec![layer],
        }
    }

    #[test]
    fn conv_lengths() {
        let net = net_with(128, Layer::conv("c", "g", 64, 128, 3, 28, 28));
        let l = accum_lengths(&net, &net.layers[0]);
        assert_eq!(l.fwd, 64 * 9);
        assert_eq!(l.bwd, 128 * 9);
        assert_eq!(l.grad, 128 * 28 * 28);
    }

    #[test]
    fn fc_lengths() {
        let net = net_with(256, Layer::fc("fc", "g", 4096, 1000));
        let l = accum_lengths(&net, &net.layers[0]);
        assert_eq!(l.fwd, 4096);
        assert_eq!(l.bwd, 1000);
        assert_eq!(l.grad, 256);
    }

    #[test]
    fn grad_dominates_early_conv_layers() {
        // The paper's core observation: GRAD lengths in early layers dwarf
        // FWD/BWD (feature maps are biggest near the input).
        let net = net_with(256, Layer::conv("conv1", "g", 3, 64, 7, 112, 112));
        let l = accum_lengths(&net, &net.layers[0]);
        assert!(l.grad > 100 * l.fwd);
        assert!(l.grad > 100 * l.bwd);
        assert_eq!(l.grad, 256 * 112 * 112); // 3.2M — the n behind (15,10)
    }

    #[test]
    fn gemm_accessor_roundtrip() {
        let a = AccumLengths {
            fwd: 1,
            bwd: 2,
            grad: 3,
        };
        assert_eq!(a.get(Gemm::Fwd), 1);
        assert_eq!(a.get(Gemm::Bwd), 2);
        assert_eq!(a.get(Gemm::Grad), 3);
        assert_eq!(Gemm::ALL.len(), 3);
    }

    #[test]
    fn gemm_name_roundtrip() {
        for g in Gemm::ALL {
            assert_eq!(Gemm::from_name(g.name()), Some(g));
        }
        assert_eq!(Gemm::from_name("fwd"), None);
    }
}
