//! Recurrent (LSTM) extension — the paper's own proposed future work
//! (§6: "training via backpropagation in time could make the GRAD
//! accumulation very large depending on the number of past time-steps
//! used. In such a case, our analysis is of great relevance").
//!
//! For an LSTM layer with input size `d_in`, hidden size `d_h`, batch
//! `B`, unrolled over `T` steps:
//!
//! * **FWD** — each gate pre-activation accumulates `d_in + d_h`
//!   products (the concatenated input·W + hidden·U dot product);
//! * **BWD** — each hidden-gradient element accumulates `4·d_h` products
//!   (all four gates feed back through U);
//! * **GRAD** — each weight gradient accumulates across the batch *and
//!   every unrolled time step*: `B · T`. This is the accumulation that
//!   grows linearly in the BPTT horizon and is where the analysis bites.

use super::lengths::AccumLengths;
use crate::vrr::solver::{min_m_acc, AccumSpec};

/// An LSTM layer's shape for accumulation-length analysis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LstmSpec {
    pub d_in: usize,
    pub d_h: usize,
    pub batch: usize,
    /// BPTT unroll horizon (time steps).
    pub timesteps: usize,
}

impl LstmSpec {
    /// The three GEMM accumulation lengths of one LSTM layer under BPTT.
    pub fn accum_lengths(&self) -> AccumLengths {
        AccumLengths {
            fwd: self.d_in + self.d_h,
            bwd: 4 * self.d_h,
            grad: self.batch * self.timesteps,
        }
    }

    /// Predicted minimum accumulator mantissa widths `(normal, chunked)`
    /// for each GEMM, at the paper's `m_p = 5` and the given NZR triple.
    pub fn predict(
        &self,
        chunk: usize,
        nzr_fwd: f64,
        nzr_bwd: f64,
        nzr_grad: f64,
    ) -> [(u32, u32); 3] {
        let l = self.accum_lengths();
        let mut out = [(0u32, 0u32); 3];
        for (slot, (n, nzr)) in out.iter_mut().zip([
            (l.fwd, nzr_fwd),
            (l.bwd, nzr_bwd),
            (l.grad, nzr_grad),
        ]) {
            let spec = AccumSpec::plain(n).with_nzr(nzr);
            *slot = (min_m_acc(&spec), min_m_acc(&spec.with_chunk(chunk)));
        }
        out
    }

    /// GRAD requirement as a function of the BPTT horizon — the curve the
    /// paper's conclusion gestures at (longer horizons, more bits).
    pub fn grad_bits_vs_horizon(&self, horizons: &[usize], nzr_grad: f64) -> Vec<(usize, u32)> {
        horizons
            .iter()
            .map(|&t| {
                let spec = AccumSpec::plain(self.batch * t).with_nzr(nzr_grad);
                (t, min_m_acc(&spec))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn medium() -> LstmSpec {
        LstmSpec {
            d_in: 512,
            d_h: 512,
            batch: 64,
            timesteps: 128,
        }
    }

    #[test]
    fn lengths_follow_bptt_structure() {
        let l = medium().accum_lengths();
        assert_eq!(l.fwd, 1024);
        assert_eq!(l.bwd, 2048);
        assert_eq!(l.grad, 64 * 128);
    }

    #[test]
    fn grad_requirement_grows_with_horizon() {
        let spec = medium();
        let curve = spec.grad_bits_vs_horizon(&[8, 32, 128, 512, 2048], 1.0);
        for w in curve.windows(2) {
            assert!(w[1].1 >= w[0].1, "{curve:?}");
        }
        // A 256x longer horizon must cost several extra bits.
        assert!(
            curve.last().unwrap().1 >= curve.first().unwrap().1 + 3,
            "{curve:?}"
        );
    }

    #[test]
    fn chunking_helps_long_horizons() {
        let spec = LstmSpec {
            timesteps: 1024,
            ..medium()
        };
        let [_, _, (grad_normal, grad_chunked)] = spec.predict(64, 1.0, 0.5, 0.5);
        assert!(grad_chunked < grad_normal);
    }

    #[test]
    fn fwd_bwd_independent_of_horizon() {
        let short = LstmSpec {
            timesteps: 4,
            ..medium()
        }
        .predict(64, 1.0, 0.5, 0.5);
        let long = LstmSpec {
            timesteps: 4096,
            ..medium()
        }
        .predict(64, 1.0, 0.5, 0.5);
        assert_eq!(short[0], long[0], "FWD must not depend on T");
        assert_eq!(short[1], long[1], "BWD must not depend on T");
        assert!(long[2].0 > short[2].0, "GRAD must depend on T");
    }
}
