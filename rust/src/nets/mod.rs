//! Network topology models for the paper's three benchmarks and the
//! machinery that turns a topology into per-layer, per-GEMM accumulation
//! lengths (paper Fig. 2) and precision predictions (Table 1).

pub mod alexnet;
pub mod layer;
pub mod lengths;
pub mod lstm;
pub mod nzr;
pub mod predict;
pub mod resnet;

pub use layer::{Layer, LayerKind, Network};
pub use lengths::{accum_lengths, AccumLengths, Gemm};
pub use predict::{predict_network, predict_network_with, LayerPrediction, NetworkPrediction};
