//! Non-zero-ratio (NZR) models (paper §4.3).
//!
//! The paper estimates NZR "by making several observations from baseline
//! data" on real GPU runs. That baseline is not reproducible here (no
//! ImageNet, no GPU farm), so we substitute documented per-network,
//! per-GEMM NZR constants — calibrated so the resulting Table 1
//! predictions track the paper's (see DESIGN.md §5) and consistent with
//! the known sparsity structure of ReLU networks:
//!
//! * FWD operands: weights (dense) × activations — conv0 sees raw images
//!   (dense); interior layers see post-ReLU activations, but the paper's
//!   FWD rows behave near-dense, so FWD keeps NZR 1.0.
//! * BWD operands: weights × ReLU-masked gradients ≈ half zero.
//! * GRAD operands: activations × gradients, both sparse — much sparser
//!   for AlexNet (the paper: "the measured sparsity of the operands was
//!   found to be much higher for AlexNet", explaining its lower GRAD
//!   precisions despite similar lengths).

use std::collections::BTreeMap;

use super::lengths::Gemm;

/// Per-GEMM NZR triple.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NzrTriple {
    pub fwd: f64,
    pub bwd: f64,
    pub grad: f64,
}

impl NzrTriple {
    pub const DENSE: NzrTriple = NzrTriple {
        fwd: 1.0,
        bwd: 1.0,
        grad: 1.0,
    };

    pub fn get(&self, g: Gemm) -> f64 {
        match g {
            Gemm::Fwd => self.fwd,
            Gemm::Bwd => self.bwd,
            Gemm::Grad => self.grad,
        }
    }
}

/// NZR model: network-wide defaults plus per-group overrides (AlexNet's
/// measured sparsity varies a lot layer to layer).
#[derive(Clone, Debug)]
pub struct NzrModel {
    pub default: NzrTriple,
    /// Overrides keyed by Table-1 group label.
    pub per_group: BTreeMap<String, NzrTriple>,
}

impl NzrModel {
    pub fn dense() -> NzrModel {
        NzrModel {
            default: NzrTriple::DENSE,
            per_group: BTreeMap::new(),
        }
    }

    pub fn uniform(fwd: f64, bwd: f64, grad: f64) -> NzrModel {
        NzrModel {
            default: NzrTriple { fwd, bwd, grad },
            per_group: BTreeMap::new(),
        }
    }

    pub fn with_group(mut self, group: &str, fwd: f64, bwd: f64, grad: f64) -> NzrModel {
        self.per_group
            .insert(group.to_string(), NzrTriple { fwd, bwd, grad });
        self
    }

    pub fn lookup(&self, group: &str, gemm: Gemm) -> f64 {
        self.per_group
            .get(group)
            .unwrap_or(&self.default)
            .get(gemm)
    }

    /// Calibrated model for the two ResNets: dense FWD, ReLU-masked BWD
    /// and GRAD operands (≈ half the products vanish).
    pub fn resnet_default() -> NzrModel {
        NzrModel::uniform(1.0, 0.5, 0.5)
    }

    /// Calibrated model for AlexNet: the paper reports much sparser GRAD
    /// operands (ReLU + max-pool routing concentrates gradients), deepest
    /// in the late convs / FC layers.
    pub fn alexnet_default() -> NzrModel {
        NzrModel::uniform(1.0, 0.5, 0.05)
            .with_group("Conv 1", 1.0, 0.5, 0.03)
            .with_group("Conv 2", 1.0, 0.5, 0.03)
            .with_group("Conv 3", 1.0, 0.5, 0.05)
            .with_group("Conv 4", 1.0, 0.5, 0.01)
            .with_group("Conv 5", 1.0, 0.5, 0.01)
            // FC gradients are much denser than the late convs' (no
            // max-pool routing behind them): paper Table 1 needs ~6 bits
            // for a batch-length-256 GRAD, consistent with NZR ≈ 0.5.
            .with_group("FC 1", 1.0, 0.5, 0.5)
            .with_group("FC 2", 1.0, 0.5, 0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_prefers_override() {
        let m = NzrModel::uniform(1.0, 0.5, 0.5).with_group("Conv 1", 0.9, 0.4, 0.1);
        assert_eq!(m.lookup("Conv 1", Gemm::Grad), 0.1);
        assert_eq!(m.lookup("Conv 2", Gemm::Grad), 0.5);
        assert_eq!(m.lookup("Conv 1", Gemm::Fwd), 0.9);
    }

    #[test]
    fn dense_model_is_all_ones() {
        let m = NzrModel::dense();
        for g in Gemm::ALL {
            assert_eq!(m.lookup("anything", g), 1.0);
        }
    }

    #[test]
    fn presets_are_in_range() {
        for m in [NzrModel::resnet_default(), NzrModel::alexnet_default()] {
            for g in Gemm::ALL {
                let v = m.lookup("Conv 1", g);
                assert!((0.0..=1.0).contains(&v));
            }
        }
    }
}
