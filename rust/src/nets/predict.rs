//! Table 1 generator: apply the VRR solver to every (layer, GEMM) of a
//! network and aggregate per Table-1 group (worst case within the group,
//! since one accumulator width is provisioned per layer group).

use std::collections::BTreeMap;

use super::layer::Network;
use super::lengths::{accum_lengths, Gemm};
use super::nzr::NzrModel;
use crate::vrr::solver::{min_m_acc, AccumSpec};

/// Predicted `(normal, chunked)` mantissa widths for one GEMM of one
/// layer or group — the ordered tuples Table 1 prints.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Prediction {
    pub normal: u32,
    pub chunked: u32,
}

/// Per-layer detail (kept for Fig. 3-style per-layer plots).
#[derive(Clone, Debug)]
pub struct LayerPrediction {
    pub layer: String,
    pub group: String,
    /// `None` for the BWD entry of the first layer (Table 1's N/A).
    pub per_gemm: BTreeMap<&'static str, Option<Prediction>>,
    pub lengths: super::lengths::AccumLengths,
}

/// Whole-network prediction: per-layer detail plus the per-group
/// aggregation that reproduces Table 1.
#[derive(Clone, Debug)]
pub struct NetworkPrediction {
    pub network: String,
    pub chunk: usize,
    pub layers: Vec<LayerPrediction>,
    /// group → gemm-name → prediction (max over the group's layers).
    pub groups: Vec<(String, BTreeMap<&'static str, Option<Prediction>>)>,
}

/// Predict accumulator mantissa widths for every layer and GEMM of `net`.
///
/// `m_p` is the product mantissa width (5 for the paper's (1,5,2) inputs)
/// and `chunk` the chunk size of the chunked-accumulation column (64 in
/// the paper).
pub fn predict_network(
    net: &Network,
    nzr: &NzrModel,
    m_p: u32,
    chunk: usize,
) -> NetworkPrediction {
    predict_network_with(net, nzr, m_p, chunk, min_m_acc)
}

/// [`predict_network`] with a pluggable solver, so callers can route the
/// per-GEMM `min_m_acc` queries through a memoized cache
/// ([`crate::api::cache`]) instead of solving each from scratch.
pub fn predict_network_with<F>(
    net: &Network,
    nzr: &NzrModel,
    m_p: u32,
    chunk: usize,
    solve: F,
) -> NetworkPrediction
where
    F: Fn(&AccumSpec) -> u32,
{
    let mut layers = Vec::new();
    for (idx, layer) in net.layers.iter().enumerate() {
        let lengths = accum_lengths(net, layer);
        let mut per_gemm: BTreeMap<&'static str, Option<Prediction>> = BTreeMap::new();
        for gemm in Gemm::ALL {
            if gemm == Gemm::Bwd && idx == net.first_layer {
                per_gemm.insert(gemm.name(), None); // Table 1's N/A
                continue;
            }
            let spec = AccumSpec {
                n: lengths.get(gemm),
                m_p,
                nzr: nzr.lookup(&layer.group, gemm),
                chunk: None,
            };
            let normal = solve(&spec);
            let chunked = solve(&spec.with_chunk(chunk));
            per_gemm.insert(
                gemm.name(),
                Some(Prediction { normal, chunked }),
            );
        }
        layers.push(LayerPrediction {
            layer: layer.name.clone(),
            group: layer.group.clone(),
            per_gemm,
            lengths,
        });
    }

    // Aggregate: max over each group (a group shares one FPU config).
    let mut groups: Vec<(String, BTreeMap<&'static str, Option<Prediction>>)> = Vec::new();
    for g in net.groups() {
        let mut agg: BTreeMap<&'static str, Option<Prediction>> = BTreeMap::new();
        for gemm in Gemm::ALL {
            let mut best: Option<Prediction> = None;
            for lp in layers.iter().filter(|lp| lp.group == g) {
                if let Some(Some(p)) = lp.per_gemm.get(gemm.name()) {
                    best = Some(match best {
                        None => *p,
                        Some(b) => Prediction {
                            normal: b.normal.max(p.normal),
                            chunked: b.chunked.max(p.chunked),
                        },
                    });
                }
            }
            agg.insert(gemm.name(), best);
        }
        groups.push((g, agg));
    }

    NetworkPrediction {
        network: net.name.clone(),
        chunk,
        layers,
        groups,
    }
}

impl NetworkPrediction {
    /// Render the Table-1 style text table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.network));
        let header: Vec<String> = std::iter::once("Layer(s)".to_string())
            .chain(self.groups.iter().map(|(g, _)| g.clone()))
            .collect();
        out.push_str(&format!("{}\n", header.join(" | ")));
        for gemm in ["FWD", "BWD", "GRAD"] {
            // A key absent from *every* group means the GEMM was filtered
            // out of this prediction (api `gemms` narrowing) — skip the
            // row. `Some(None)` stays an N/A cell, not a missing row.
            if !self.groups.iter().any(|(_, agg)| agg.contains_key(gemm)) {
                continue;
            }
            let mut row = vec![gemm.to_string()];
            for (_, agg) in &self.groups {
                row.push(match agg.get(gemm) {
                    Some(Some(p)) => format!("({},{})", p.normal, p.chunked),
                    _ => "N/A".to_string(),
                });
            }
            out.push_str(&format!("{}\n", row.join(" | ")));
        }
        out
    }

    /// Look up the group-level prediction for (group, gemm).
    pub fn group_prediction(&self, group: &str, gemm: &str) -> Option<Prediction> {
        self.groups
            .iter()
            .find(|(g, _)| g == group)
            .and_then(|(_, agg)| agg.get(gemm).copied().flatten())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::alexnet::alexnet_imagenet;
    use crate::nets::nzr::NzrModel;
    use crate::nets::resnet::{resnet18_imagenet, resnet32_cifar10};

    #[test]
    fn first_layer_bwd_is_na() {
        let net = resnet32_cifar10();
        let pred = predict_network(&net, &NzrModel::resnet_default(), 5, 64);
        assert_eq!(pred.group_prediction("Conv 0", "BWD"), None);
        assert!(pred.group_prediction("Conv 0", "FWD").is_some());
    }

    #[test]
    fn chunked_never_needs_more_bits() {
        for (net, nzr) in [
            (resnet32_cifar10(), NzrModel::resnet_default()),
            (resnet18_imagenet(), NzrModel::resnet_default()),
            (alexnet_imagenet(), NzrModel::alexnet_default()),
        ] {
            let pred = predict_network(&net, &nzr, 5, 64);
            for (g, agg) in &pred.groups {
                for (gemm, p) in agg {
                    if let Some(p) = p {
                        assert!(
                            p.chunked <= p.normal,
                            "{} {g} {gemm}: chunked {} > normal {}",
                            net.name,
                            p.chunked,
                            p.normal
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn grad_needs_most_precision_near_input() {
        // Paper Table 1 caption: "GRAD … needs the most precision for
        // layers/blocks close to the input".
        let net = resnet18_imagenet();
        let pred = predict_network(&net, &NzrModel::resnet_default(), 5, 64);
        let g0 = pred.group_prediction("Conv 0", "GRAD").unwrap();
        let g4 = pred.group_prediction("ResBlock 4", "GRAD").unwrap();
        assert!(g0.normal > g4.normal, "{} vs {}", g0.normal, g4.normal);
        let f0 = pred.group_prediction("Conv 0", "FWD").unwrap();
        assert!(g0.normal > f0.normal);
    }

    #[test]
    fn cifar_needs_less_than_imagenet() {
        // Paper: "The required accumulation precision for CIFAR-10
        // ResNet 32 is in general lower than that of the ImageNet
        // networks" (shorter dot products).
        let c = predict_network(&resnet32_cifar10(), &NzrModel::resnet_default(), 5, 64);
        let i = predict_network(&resnet18_imagenet(), &NzrModel::resnet_default(), 5, 64);
        let cmax = c
            .groups
            .iter()
            .flat_map(|(_, a)| a.values().flatten())
            .map(|p| p.normal)
            .max()
            .unwrap();
        let imax = i
            .groups
            .iter()
            .flat_map(|(_, a)| a.values().flatten())
            .map(|p| p.normal)
            .max()
            .unwrap();
        assert!(cmax < imax, "cifar {cmax} vs imagenet {imax}");
    }

    #[test]
    fn render_contains_all_groups() {
        let net = alexnet_imagenet();
        let pred = predict_network(&net, &NzrModel::alexnet_default(), 5, 64);
        let text = pred.render();
        for g in net.groups() {
            assert!(text.contains(&g), "missing {g} in:\n{text}");
        }
        assert!(text.contains("N/A"));
    }
}
