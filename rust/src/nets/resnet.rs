//! ResNet topologies: CIFAR-10 ResNet-32 (He et al. 2015 §4.2 family:
//! 3 stages × 5 basic blocks of two 3×3 convs) and ImageNet ResNet-18
//! (4 stages × 2 basic blocks), with the group labels of paper Table 1.

use super::layer::{Layer, Network};

/// CIFAR-10 ResNet-32, batch 128 (the configuration of Wang et al. 2018).
///
/// conv0: 3→16 @ 32×32, then stages of 5 basic blocks:
/// ResBlock 1: 16→16 @ 32×32, ResBlock 2: 16/32→32 @ 16×16,
/// ResBlock 3: 32/64→64 @ 8×8.
pub fn resnet32_cifar10() -> Network {
    let mut layers = vec![Layer::conv("conv0", "Conv 0", 3, 16, 3, 32, 32)];
    let stages: [(usize, usize, usize, &str); 3] = [
        (16, 32, 1, "ResBlock 1"),
        (32, 16, 2, "ResBlock 2"),
        (64, 8, 3, "ResBlock 3"),
    ];
    let mut c_prev = 16;
    for (c, hw, stage, group) in stages {
        for b in 0..5 {
            let c_in_first = if b == 0 { c_prev } else { c };
            layers.push(Layer::conv(
                &format!("conv{stage}_{b}a"),
                group,
                c_in_first,
                c,
                3,
                hw,
                hw,
            ));
            layers.push(Layer::conv(
                &format!("conv{stage}_{b}b"),
                group,
                c,
                c,
                3,
                hw,
                hw,
            ));
        }
        c_prev = c;
    }
    Network {
        name: "CIFAR-10 ResNet 32".into(),
        batch: 128,
        layers,
        first_layer: 0,
    }
}

/// ImageNet ResNet-18, batch 256.
///
/// conv0: 7×7, 3→64, output 112×112; stages of 2 basic blocks:
/// ResBlock 1: 64 @ 56×56, ResBlock 2: 128 @ 28×28,
/// ResBlock 3: 256 @ 14×14, ResBlock 4: 512 @ 7×7.
pub fn resnet18_imagenet() -> Network {
    let mut layers = vec![Layer::conv("conv0", "Conv 0", 3, 64, 7, 112, 112)];
    let stages: [(usize, usize, usize, &str); 4] = [
        (64, 56, 1, "ResBlock 1"),
        (128, 28, 2, "ResBlock 2"),
        (256, 14, 3, "ResBlock 3"),
        (512, 7, 4, "ResBlock 4"),
    ];
    let mut c_prev = 64;
    for (c, hw, stage, group) in stages {
        for b in 0..2 {
            let c_in_first = if b == 0 { c_prev } else { c };
            layers.push(Layer::conv(
                &format!("conv{stage}_{b}a"),
                group,
                c_in_first,
                c,
                3,
                hw,
                hw,
            ));
            layers.push(Layer::conv(
                &format!("conv{stage}_{b}b"),
                group,
                c,
                c,
                3,
                hw,
                hw,
            ));
        }
        c_prev = c;
    }
    Network {
        name: "ImageNet ResNet 18".into(),
        batch: 256,
        layers,
        first_layer: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::lengths::accum_lengths;

    #[test]
    fn resnet32_layer_count() {
        // 1 stem + 3 stages × 5 blocks × 2 convs = 31 weight convs
        // (+ the FC classifier which the paper keeps at 16-b and excludes).
        let net = resnet32_cifar10();
        assert_eq!(net.layers.len(), 31);
        assert_eq!(
            net.groups(),
            vec!["Conv 0", "ResBlock 1", "ResBlock 2", "ResBlock 3"]
        );
    }

    #[test]
    fn resnet32_grad_lengths_quadruple_between_blocks() {
        // Paper §3: "The GRAD accumulation length in the former is much
        // longer (4×) than the latter" — halving H,W quarters B·H·W.
        let net = resnet32_cifar10();
        let b1 = net.layers.iter().find(|l| l.group == "ResBlock 1").unwrap();
        let b2 = net.layers.iter().find(|l| l.group == "ResBlock 2").unwrap();
        let g1 = accum_lengths(&net, b1).grad;
        let g2 = accum_lengths(&net, b2).grad;
        assert_eq!(g1, 4 * g2);
        assert_eq!(g1, 128 * 32 * 32);
    }

    #[test]
    fn resnet18_shapes() {
        let net = resnet18_imagenet();
        assert_eq!(net.layers.len(), 17);
        assert_eq!(net.batch, 256);
        let conv0 = &net.layers[0];
        let l = accum_lengths(&net, conv0);
        assert_eq!(l.fwd, 3 * 49);
        assert_eq!(l.grad, 256 * 112 * 112); // 3,211,264
        // Channel growth doubles each stage.
        let last = net.layers.last().unwrap();
        assert_eq!(last.c_out, 512);
        assert_eq!((last.h_out, last.w_out), (7, 7));
    }

    #[test]
    fn resnet18_param_count_sane() {
        // ~11M conv params for ResNet-18 (no FC): we count 10.99M.
        let net = resnet18_imagenet();
        let p = net.total_params();
        assert!(
            (9_000_000..13_000_000).contains(&p),
            "params={p}"
        );
    }

    #[test]
    fn first_conv_is_marked() {
        assert_eq!(resnet32_cifar10().first_layer, 0);
        assert_eq!(resnet18_imagenet().first_layer, 0);
    }
}
