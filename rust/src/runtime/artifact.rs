//! Artifact registry: discovery and naming of the AOT-compiled HLO-text
//! artifacts produced by `make artifacts` (python/compile/aot.py).
//!
//! Naming convention (shared with aot.py):
//! `train_step_<variant>.hlo.txt` where `<variant>` encodes the
//! accumulation precision plan, e.g. `baseline`, `macc12`,
//! `macc12_chunk64`. A `manifest.json` written by aot.py records the
//! model dimensions each artifact was lowered for.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Model dimensions an artifact set was lowered for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelDims {
    pub batch: usize,
    pub dim: usize,
    pub hidden: usize,
    pub classes: usize,
}

/// The artifact directory with its manifest.
#[derive(Clone, Debug)]
pub struct ArtifactStore {
    pub root: PathBuf,
    pub dims: ModelDims,
    /// variant name → artifact path.
    pub variants: BTreeMap<String, PathBuf>,
}

impl ArtifactStore {
    /// Open an artifact directory and parse its manifest.
    pub fn open(root: impl AsRef<Path>) -> Result<ArtifactStore> {
        let root = root.as_ref().to_path_buf();
        let manifest_path = root.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let get = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_f64)
                .map(|v| v as usize)
                .with_context(|| format!("manifest missing '{k}'"))
        };
        let dims = ModelDims {
            batch: get("batch")?,
            dim: get("dim")?,
            hidden: get("hidden")?,
            classes: get("classes")?,
        };
        let mut variants = BTreeMap::new();
        if let Some(arr) = j.get("variants").and_then(Json::as_arr) {
            for v in arr {
                if let Some(name) = v.as_str() {
                    let p = root.join(format!("train_step_{name}.hlo.txt"));
                    variants.insert(name.to_string(), p);
                }
            }
        }
        if variants.is_empty() {
            bail!("manifest lists no variants");
        }
        Ok(ArtifactStore {
            root,
            dims,
            variants,
        })
    }

    /// Path of a variant's HLO artifact (error lists available ones).
    pub fn path(&self, variant: &str) -> Result<&Path> {
        match self.variants.get(variant) {
            Some(p) => Ok(p),
            None => bail!(
                "unknown variant '{variant}'; available: {}",
                self.variants
                    .keys()
                    .cloned()
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        }
    }

    /// Check that every listed artifact file actually exists.
    pub fn verify(&self) -> Result<()> {
        for (name, path) in &self.variants {
            if !path.exists() {
                bail!("artifact for '{name}' missing: {}", path.display());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    fn write_manifest(dir: &Path, variants: &[&str]) {
        let vs: Vec<String> = variants.iter().map(|v| format!("\"{v}\"")).collect();
        fs::write(
            dir.join("manifest.json"),
            format!(
                r#"{{"batch":32,"dim":256,"hidden":64,"classes":10,"variants":[{}]}}"#,
                vs.join(",")
            ),
        )
        .unwrap();
    }

    #[test]
    fn open_and_lookup() {
        let dir = std::env::temp_dir().join("abws_artifact_test_1");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, &["baseline", "macc12"]);
        let store = ArtifactStore::open(&dir).unwrap();
        assert_eq!(store.dims.batch, 32);
        assert_eq!(store.dims.classes, 10);
        assert!(store
            .path("macc12")
            .unwrap()
            .ends_with("train_step_macc12.hlo.txt"));
        assert!(store.path("nope").is_err());
        let err = format!("{:#}", store.path("nope").unwrap_err());
        assert!(err.contains("baseline"), "{err}");
    }

    #[test]
    fn verify_detects_missing_files() {
        let dir = std::env::temp_dir().join("abws_artifact_test_2");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, &["baseline"]);
        let store = ArtifactStore::open(&dir).unwrap();
        assert!(store.verify().is_err());
        fs::write(dir.join("train_step_baseline.hlo.txt"), "HloModule x").unwrap();
        assert!(store.verify().is_ok());
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = std::env::temp_dir().join("abws_artifact_test_none");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        assert!(ArtifactStore::open(&dir).is_err());
    }
}
