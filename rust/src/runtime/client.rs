//! Thin ownership wrapper around the PJRT CPU client plus HLO-text
//! loading and literal conversion helpers.

use anyhow::{Context, Result};
use std::path::Path;

use crate::softfloat::tensor::Tensor;

/// The PJRT runtime: one CPU client, many compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it for this client.
    ///
    /// HLO text (not a serialized `HloModuleProto`) is the interchange
    /// format: jax ≥ 0.5 emits 64-bit instruction ids that xla_extension
    /// 0.5.1 rejects; the text parser reassigns ids.
    pub fn compile_hlo_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    /// Execute a compiled artifact on literal inputs, returning the
    /// flattened output tuple.
    pub fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → always a tuple.
        Ok(result.to_tuple()?)
    }
}

/// Convert a [`Tensor`] into an f32 literal of the same shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(&t.data).reshape(&dims)?)
}

/// Convert an f32 literal back into a [`Tensor`].
pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l.to_vec::<f32>()?;
    Ok(Tensor::from_vec(&dims, data))
}

/// Build an i32 label literal `[n]` from usize labels.
pub fn labels_to_literal(y: &[usize]) -> Result<xla::Literal> {
    let v: Vec<i32> = y.iter().map(|&c| c as i32).collect();
    let dims = [v.len() as i64];
    Ok(xla::Literal::vec1(&v).reshape(&dims)?)
}

/// Extract a scalar f32 from a literal (loss values etc.).
pub fn scalar_f32(l: &xla::Literal) -> Result<f32> {
    Ok(l.to_vec::<f32>()?[0])
}
