//! The AOT train-step executor: owns the compiled HLO train step and the
//! parameter/optimizer state (as literals), and advances training one
//! batch at a time from Rust. This is the L3 hot path — no Python.
//!
//! Artifact calling convention (must match python/compile/model.py):
//! inputs  `(w1, w2, m1, m2, x, y)`;
//! outputs `(w1', w2', m1', m2', loss, acc)` as a flat tuple.

use anyhow::{ensure, Context, Result};

use super::artifact::{ArtifactStore, ModelDims};
use super::client::{labels_to_literal, scalar_f32, tensor_to_literal, Runtime};
use crate::data::synth::Dataset;
use crate::softfloat::tensor::Tensor;
use crate::trainer::metrics::{RunMetrics, StepRecord};
use crate::util::rng::Pcg64;

/// Executor for one compiled train-step variant.
pub struct TrainStepExecutor<'rt> {
    rt: &'rt Runtime,
    exe: xla::PjRtLoadedExecutable,
    pub dims: ModelDims,
    /// `[w1, w2, m1, m2]` — carried across steps as literals.
    state: Vec<xla::Literal>,
    pub variant: String,
}

impl<'rt> TrainStepExecutor<'rt> {
    /// Compile `variant` from `store` and He-initialize the parameters.
    pub fn new(
        rt: &'rt Runtime,
        store: &ArtifactStore,
        variant: &str,
        seed: u64,
    ) -> Result<Self> {
        let path = store.path(variant)?;
        let exe = rt
            .compile_hlo_file(path)
            .with_context(|| format!("compiling variant '{variant}'"))?;
        let d = store.dims;
        let mut rng = Pcg64::seeded(seed);
        let w1 = Tensor::randn(&[d.dim, d.hidden], (2.0 / d.dim as f64).sqrt(), &mut rng);
        let w2 = Tensor::randn(
            &[d.hidden, d.classes],
            (2.0 / d.hidden as f64).sqrt(),
            &mut rng,
        );
        let m1 = Tensor::zeros(&[d.dim, d.hidden]);
        let m2 = Tensor::zeros(&[d.hidden, d.classes]);
        let state = vec![
            tensor_to_literal(&w1)?,
            tensor_to_literal(&w2)?,
            tensor_to_literal(&m1)?,
            tensor_to_literal(&m2)?,
        ];
        Ok(TrainStepExecutor {
            rt,
            exe,
            dims: d,
            state,
            variant: variant.to_string(),
        })
    }

    /// One training step; returns `(loss, train_acc)`.
    pub fn step(&mut self, x: &Tensor, y: &[usize]) -> Result<(f64, f64)> {
        ensure!(
            x.shape == vec![self.dims.batch, self.dims.dim],
            "batch shape {:?} does not match artifact dims {:?}",
            x.shape,
            self.dims
        );
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(6);
        inputs.append(&mut self.state);
        inputs.push(tensor_to_literal(x)?);
        inputs.push(labels_to_literal(y)?);
        let mut outs = self.rt.run(&self.exe, &inputs)?;
        ensure!(outs.len() == 6, "expected 6 outputs, got {}", outs.len());
        let acc = scalar_f32(&outs[5])? as f64;
        let loss = scalar_f32(&outs[4])? as f64;
        outs.truncate(4);
        self.state = outs;
        Ok((loss, acc))
    }

    /// Train over a dataset for `steps` batches; returns the metric trace.
    pub fn train(&mut self, data: &Dataset, steps: usize) -> Result<RunMetrics> {
        let mut metrics = RunMetrics::default();
        for step in 0..steps {
            let (xb, yb) = data.batch(step, self.dims.batch);
            let (loss, train_acc) = self.step(&xb, &yb)?;
            metrics.push(StepRecord {
                step,
                loss,
                train_acc,
            });
            if metrics.diverged {
                break;
            }
        }
        Ok(metrics)
    }

    /// Current parameter tensors `(w1, w2)` copied back to host tensors.
    pub fn params(&self) -> Result<(Tensor, Tensor)> {
        let w1 = super::client::literal_to_tensor(&self.state[0])?;
        let w2 = super::client::literal_to_tensor(&self.state[1])?;
        Ok((w1, w2))
    }
}
