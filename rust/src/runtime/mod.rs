//! PJRT runtime: loads the HLO-text artifacts AOT-compiled by
//! `python/compile/aot.py` and executes them on the CPU PJRT client —
//! Python is never on this path (see /opt/xla-example/load_hlo for the
//! interchange rationale: HLO *text*, not serialized protos).
//!
//! The PJRT-backed pieces ([`client`], [`exec`]) need the external `xla`
//! bindings crate and a libxla install, so they are gated behind the
//! `pjrt` cargo feature; the pure-Rust artifact registry ([`artifact`])
//! is always available. Builds without the feature still discover and
//! verify artifact directories — they just cannot execute them, and the
//! CLI reports that with a clear error instead of failing to link.
//!
//! [`pool`] is independent of PJRT: the persistent worker pool the
//! softfloat GEMM kernel parallelizes over (always available).

pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod exec;
pub mod pool;

pub use artifact::ArtifactStore;
#[cfg(feature = "pjrt")]
pub use client::Runtime;
#[cfg(feature = "pjrt")]
pub use exec::TrainStepExecutor;
pub use pool::WorkerPool;
