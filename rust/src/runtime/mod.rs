//! PJRT runtime: loads the HLO-text artifacts AOT-compiled by
//! `python/compile/aot.py` and executes them on the CPU PJRT client —
//! Python is never on this path (see /opt/xla-example/load_hlo for the
//! interchange rationale: HLO *text*, not serialized protos).

pub mod artifact;
pub mod client;
pub mod exec;

pub use artifact::ArtifactStore;
pub use client::Runtime;
pub use exec::TrainStepExecutor;
