//! Persistent worker pool for data-parallel kernels.
//!
//! `std::thread::scope` (see `coordinator::sweep`) is fine for coarse
//! sweeps, but the GEMM hot path enters a parallel region for every
//! matrix product — respawning OS threads each time would swamp the work
//! itself. This pool keeps plain `std::thread` workers alive across
//! regions: a caller publishes one job, `threads - 1` pool workers claim
//! it, the caller participates too, and everyone meets at a completion
//! latch before the call returns.
//!
//! Design rules:
//!
//! 1. **The job splits its own work.** A region's job is a single
//!    `Fn() + Sync` closure invoked once per participant; participants
//!    coordinate through whatever the closure captures (typically an
//!    atomic index over row panels). The pool knows nothing about the
//!    work's shape.
//! 2. **One region at a time; excess callers run alone.** The region
//!    lock is acquired with `try_lock`: a caller that finds the pool busy
//!    (a concurrent serve worker, or a nested region) just runs the job
//!    on its own thread. Kernels built on this pool must therefore be
//!    *participant-count independent* — which the reduced-precision GEMM
//!    is by construction (every output element is an independent dot
//!    product), so the fallback is always bit-identical.
//! 3. **Panics do not poison the pool.** Workers run jobs under
//!    `catch_unwind`; a worker panic is re-raised on the caller after the
//!    latch, and the worker itself survives for the next region.
//!
//! The lifetime of the published closure is erased to `'static` while a
//! region is open; this is sound because [`WorkerPool::run`] does not
//! return (or unwind) until every participant has finished with it.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;
use std::time::Instant;

use crate::telemetry::trace;

/// What a parallel region reports back: region wall time and per
/// participant busy time (the caller first, pool workers after, in
/// completion order).
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    pub wall_ns: u64,
    pub busy_ns: Vec<u64>,
}

impl RunReport {
    /// Busy share of the region wall clock per participant, in percent
    /// (clamped to 100 — timer granularity can nudge a busy worker over).
    pub fn utilization_pct(&self) -> impl Iterator<Item = u64> + '_ {
        let wall = self.wall_ns.max(1);
        self.busy_ns
            .iter()
            .map(move |&b| (b.saturating_mul(100) / wall).min(100))
    }
}

type Job = &'static (dyn Fn() + Sync);

struct State {
    /// The open region's job; `None` between regions.
    job: Option<Job>,
    /// The spawning span of the open region, if tracing is enabled:
    /// workers install it as their ambient parent so spans opened inside
    /// the job attach to the caller's span tree.
    ctx: Option<trace::SpanCtx>,
    /// Bumped once per region so sleeping workers can tell a new job
    /// from a spurious wakeup or an already-drained one.
    epoch: u64,
    /// Worker claims still available for the open region.
    unclaimed: usize,
    /// Claimed worker executions not yet finished (the latch count).
    running: usize,
    panicked: bool,
    busy_ns: Vec<u64>,
}

struct Shared {
    state: Mutex<State>,
    /// Signals workers that a region opened.
    work_cv: Condvar,
    /// Signals the caller that the last claimed worker finished.
    done_cv: Condvar,
}

/// A persistent pool of `std::thread` workers; see the module docs.
pub struct WorkerPool {
    shared: Arc<Shared>,
    /// Workers spawned so far (grown lazily, never shrunk).
    spawned: Mutex<usize>,
    /// Held for the duration of one parallel region.
    region: Mutex<()>,
}

/// Install the region's spawning span as this worker's ambient parent —
/// only when one was captured (tracing on *and* the caller had a span).
fn set_ambient_if(ctx: Option<trace::SpanCtx>) -> Option<trace::AmbientGuard> {
    ctx.map(|c| trace::set_ambient(Some(c)))
}

/// A `pool.region` span for one participant of a traced region. Inert
/// when the region carries no spawning span.
fn region_span(ctx: Option<trace::SpanCtx>) -> trace::TraceSpan {
    if ctx.is_some() {
        trace::TraceSpan::enter("pool.region")
    } else {
        trace::TraceSpan::noop()
    }
}

fn worker_loop(shared: Arc<Shared>) {
    let mut seen = 0u64;
    loop {
        let (job, ctx) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.epoch != seen {
                    seen = st.epoch;
                    if st.unclaimed > 0 {
                        st.unclaimed -= 1;
                        break (st.job.expect("open region with no job"), st.ctx);
                    }
                    // Region already fully claimed — wait for the next.
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        let t0 = Instant::now();
        let result = catch_unwind(AssertUnwindSafe(|| {
            // Adopt the spawning span as parent for anything the job
            // traces on this thread; both guards unwind-safely restore
            // state if the job panics.
            let _ambient = set_ambient_if(ctx);
            let _span = region_span(ctx);
            job()
        }));
        let busy = t0.elapsed().as_nanos() as u64;
        let mut st = shared.state.lock().unwrap();
        st.busy_ns.push(busy);
        if result.is_err() {
            st.panicked = true;
        }
        st.running -= 1;
        if st.running == 0 {
            shared.done_cv.notify_all();
        }
    }
}

impl WorkerPool {
    fn new() -> WorkerPool {
        WorkerPool {
            shared: Arc::new(Shared {
                state: Mutex::new(State {
                    job: None,
                    ctx: None,
                    epoch: 0,
                    unclaimed: 0,
                    running: 0,
                    panicked: false,
                    busy_ns: Vec::new(),
                }),
                work_cv: Condvar::new(),
                done_cv: Condvar::new(),
            }),
            spawned: Mutex::new(0),
            region: Mutex::new(()),
        }
    }

    fn ensure_workers(&self, want: usize) {
        let mut n = self.spawned.lock().unwrap();
        while *n < want {
            let shared = Arc::clone(&self.shared);
            thread::Builder::new()
                .name(format!("abws-pool-{n}"))
                .spawn(move || worker_loop(shared))
                .expect("failed to spawn pool worker");
            *n += 1;
        }
    }

    /// Run `f` once on each of `threads` participants: the calling thread
    /// plus `threads - 1` pool workers. Blocks until every participant
    /// has returned. If `threads <= 1`, or another region is already
    /// open, the caller runs `f` alone (see the module docs for why that
    /// must be equivalent).
    pub fn run(&self, threads: usize, f: &(dyn Fn() + Sync)) -> RunReport {
        // Captured once per region: the span the region's participants
        // parent onto. `None` whenever tracing is off (one relaxed load).
        let ctx = if trace::enabled() {
            trace::current()
        } else {
            None
        };
        let region = if threads > 1 {
            self.region.try_lock().ok()
        } else {
            None
        };
        let Some(_region) = region else {
            let t0 = Instant::now();
            {
                let _span = region_span(ctx);
                f();
            }
            let ns = t0.elapsed().as_nanos() as u64;
            return RunReport {
                wall_ns: ns.max(1),
                busy_ns: vec![ns],
            };
        };

        let helpers = threads - 1;
        self.ensure_workers(helpers);
        // Erase the borrow lifetime for the worker threads. Sound: this
        // function waits on the completion latch below before returning
        // or unwinding, so no worker can still hold the reference once
        // the caller's borrow of `f` ends.
        let job: Job =
            unsafe { std::mem::transmute::<&(dyn Fn() + Sync), &'static (dyn Fn() + Sync)>(f) };

        let wall = Instant::now();
        {
            let mut st = self.shared.state.lock().unwrap();
            st.job = Some(job);
            st.ctx = ctx;
            st.epoch = st.epoch.wrapping_add(1);
            st.unclaimed = helpers;
            st.running = helpers;
            st.panicked = false;
            st.busy_ns.clear();
        }
        self.shared.work_cv.notify_all();

        let t0 = Instant::now();
        let caller = catch_unwind(AssertUnwindSafe(|| {
            let _span = region_span(ctx);
            f()
        }));
        let caller_busy = t0.elapsed().as_nanos() as u64;

        let (worker_panicked, mut busy_ns) = {
            let mut st = self.shared.state.lock().unwrap();
            while st.running != 0 {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            st.job = None;
            st.ctx = None;
            (st.panicked, std::mem::take(&mut st.busy_ns))
        };
        let wall_ns = wall.elapsed().as_nanos() as u64;
        busy_ns.insert(0, caller_busy);

        // Release the region before any panic re-raise: unwinding while
        // holding the guard would poison the region mutex and silently
        // degrade every future region to the inline fallback.
        drop(_region);
        if let Err(p) = caller {
            resume_unwind(p);
        }
        assert!(
            !worker_panicked,
            "pool worker panicked inside a parallel region"
        );
        RunReport {
            wall_ns: wall_ns.max(1),
            busy_ns,
        }
    }
}

/// The process-wide pool all kernels share.
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(WorkerPool::new)
}

/// Run `f` on the process-wide pool; see [`WorkerPool::run`].
pub fn run(threads: usize, f: &(dyn Fn() + Sync)) -> RunReport {
    global().run(threads, f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

    // Tests that assert exact participant counts use a private pool:
    // the global pool is shared process-wide, so a concurrently running
    // test could hold its region and force the inline fallback here.

    /// Drain 0..n through an atomic index, summing into `total`.
    fn drain_sum(n: u64, next: &AtomicU64, total: &AtomicU64) {
        loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            total.fetch_add(i, Ordering::Relaxed);
        }
    }

    #[test]
    fn all_participants_run_and_work_is_complete() {
        let pool = WorkerPool::new();
        let n = 10_000u64;
        let next = AtomicU64::new(0);
        let total = AtomicU64::new(0);
        let calls = AtomicUsize::new(0);
        let report = pool.run(4, &|| {
            calls.fetch_add(1, Ordering::Relaxed);
            drain_sum(n, &next, &total);
        });
        assert_eq!(total.load(Ordering::Relaxed), n * (n - 1) / 2);
        assert_eq!(calls.load(Ordering::Relaxed), 4);
        assert_eq!(report.busy_ns.len(), 4);
        assert!(report.utilization_pct().all(|p| p <= 100));
    }

    #[test]
    fn single_thread_runs_inline() {
        let calls = AtomicUsize::new(0);
        let report = run(1, &|| {
            calls.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(calls.load(Ordering::Relaxed), 1);
        assert_eq!(report.busy_ns.len(), 1);
    }

    #[test]
    fn pool_is_reusable_across_regions() {
        let pool = WorkerPool::new();
        for round in 1..=5u64 {
            let n = 1_000 * round;
            let next = AtomicU64::new(0);
            let total = AtomicU64::new(0);
            pool.run(3, &|| drain_sum(n, &next, &total));
            assert_eq!(total.load(Ordering::Relaxed), n * (n - 1) / 2);
        }
    }

    #[test]
    fn nested_region_falls_back_to_inline() {
        // A job that opens another region on the same pool while one is
        // live: the inner call must not deadlock; it runs inline on the
        // calling participant.
        let pool = WorkerPool::new();
        let inner_calls = AtomicUsize::new(0);
        let report = pool.run(2, &|| {
            let r = pool.run(2, &|| {
                inner_calls.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(r.busy_ns.len(), 1, "inner region must run inline");
        });
        assert_eq!(report.busy_ns.len(), 2);
        // One inline inner run per outer participant.
        assert_eq!(inner_calls.load(Ordering::Relaxed), 2);
    }

    #[test]
    #[should_panic]
    fn participant_panic_propagates_to_caller() {
        let pool = WorkerPool::new();
        let hits = AtomicUsize::new(0);
        pool.run(2, &|| {
            // Exactly one participant panics — whichever claims first.
            if hits.fetch_add(1, Ordering::Relaxed) == 0 {
                panic!("injected participant panic");
            }
        });
    }

    #[test]
    fn pool_survives_a_panicked_region() {
        let pool = WorkerPool::new();
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(2, &|| panic!("injected"));
        }));
        // The next region must still complete on the same workers.
        let n = 2_000u64;
        let next = AtomicU64::new(0);
        let total = AtomicU64::new(0);
        pool.run(2, &|| drain_sum(n, &next, &total));
        assert_eq!(total.load(Ordering::Relaxed), n * (n - 1) / 2);
    }
}
