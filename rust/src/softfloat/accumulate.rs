//! Accumulation algorithms under reduced precision: sequential, two-level
//! chunked (paper §4.2, Wang et al. 2018), and pairwise (tree) reduction
//! as a classical stable baseline, plus an exact Neumaier reference sum.
//!
//! The sums run on the precomputed-constant [`Quantizer`] fast path, the
//! same machinery the parallel GEMM kernel uses: the `*_q` entry points
//! are monomorphized per [`RoundMode`] (`Rne`/`Rtz`) so the per-element
//! rounding dispatch disappears, the format constants are resolved once
//! per call instead of once per element, and a target at least as wide as
//! f64 short-circuits to the plain-f64 sum (the `man_bits >= 52` identity
//! fast path — bit-identical because identity quantization is a
//! pass-through). The original free-`quantize` implementations are kept
//! verbatim as `*_ref` oracles; the `quantizer_sums_match_reference`
//! tests below (and the `mc_engine` integration suite) pin the two paths
//! bit-for-bit.

use super::arith::RpArith;
use super::format::FpFormat;
use super::quant::{quantize, Quantizer, Rne, RoundMode, Rounding, Rtz};

/// Streaming reduced-precision accumulator (the hardware register model).
/// The accumulator-format constants are hoisted into a [`Quantizer`] at
/// construction, so `push` pays no per-element format decoding.
#[derive(Clone, Debug)]
pub struct Accumulator {
    arith: RpArith,
    acc_q: Quantizer,
    sum: f64,
    count: u64,
}

impl Accumulator {
    pub fn new(arith: RpArith) -> Self {
        Accumulator {
            acc_q: Quantizer::new(arith.acc, arith.mode),
            arith,
            sum: 0.0,
            count: 0,
        }
    }

    /// Add one (already product-quantized) term.
    #[inline]
    pub fn push(&mut self, p: f64) {
        self.sum = self.acc_q.quantize(self.sum + p);
        self.count += 1;
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// The arithmetic context this accumulator simulates.
    pub fn arith(&self) -> &RpArith {
        &self.arith
    }
}

/// Sequential reduced-precision sum: `s_{i} = rnd(s_{i-1} + p_i)`.
pub fn sequential_sum(terms: &[f64], acc_fmt: FpFormat, mode: Rounding) -> f64 {
    let q = Quantizer::new(acc_fmt, mode);
    // 1-in-K numerics health sample — an observer only; the returned
    // sum is computed by the same fast path as always.
    if crate::telemetry::health::should_sample() {
        crate::telemetry::health::observe("accumulate", terms, acc_fmt, mode, None, None);
    }
    match mode {
        Rounding::NearestEven => sequential_sum_q::<Rne>(terms, &q),
        Rounding::TowardZero => sequential_sum_q::<Rtz>(terms, &q),
    }
}

/// [`sequential_sum`] monomorphized per rounding mode on a prebuilt
/// [`Quantizer`] — the entry point hot loops (the MC engine) call after
/// resolving `R` once per configuration instead of once per element.
#[inline]
pub fn sequential_sum_q<R: RoundMode>(terms: &[f64], q: &Quantizer) -> f64 {
    if q.is_identity() {
        return identity_sum(terms);
    }
    let mut s = 0.0;
    for &p in terms {
        s = q.quantize_m::<R>(s + p);
    }
    s
}

/// Two-level chunked reduced-precision sum (paper §4.2): split into
/// chunks of `chunk` terms, accumulate each chunk sequentially at
/// `acc_fmt`, then accumulate the chunk results sequentially at `acc_fmt`.
///
/// A trailing partial chunk is handled naturally (shorter intra sum).
pub fn chunked_sum(terms: &[f64], chunk: usize, acc_fmt: FpFormat, mode: Rounding) -> f64 {
    let q = Quantizer::new(acc_fmt, mode);
    // Same 1-in-K health observer as `sequential_sum`.
    if chunk > 0 && crate::telemetry::health::should_sample() {
        crate::telemetry::health::observe("accumulate", terms, acc_fmt, mode, None, Some(chunk));
    }
    match mode {
        Rounding::NearestEven => chunked_sum_q::<Rne>(terms, chunk, &q),
        Rounding::TowardZero => chunked_sum_q::<Rtz>(terms, chunk, &q),
    }
}

/// [`chunked_sum`] monomorphized per rounding mode on a prebuilt
/// [`Quantizer`]; see [`sequential_sum_q`].
#[inline]
pub fn chunked_sum_q<R: RoundMode>(terms: &[f64], chunk: usize, q: &Quantizer) -> f64 {
    assert!(chunk > 0, "chunk size must be positive");
    if q.is_identity() {
        return identity_chunked_sum(terms, chunk);
    }
    let mut inter = 0.0;
    for block in terms.chunks(chunk) {
        let mut intra = 0.0;
        for &p in block {
            intra = q.quantize_m::<R>(intra + p);
        }
        inter = q.quantize_m::<R>(inter + intra);
    }
    inter
}

/// Pairwise (binary-tree) reduced-precision sum — the classical
/// `O(log n)`-error algorithm, used as an ablation baseline against the
/// paper's chunked scheme.
pub fn pairwise_sum(terms: &[f64], acc_fmt: FpFormat, mode: Rounding) -> f64 {
    let q = Quantizer::new(acc_fmt, mode);
    match mode {
        Rounding::NearestEven => pairwise_sum_q::<Rne>(terms, &q),
        Rounding::TowardZero => pairwise_sum_q::<Rtz>(terms, &q),
    }
}

/// [`pairwise_sum`] monomorphized per rounding mode on a prebuilt
/// [`Quantizer`]; see [`sequential_sum_q`].
pub fn pairwise_sum_q<R: RoundMode>(terms: &[f64], q: &Quantizer) -> f64 {
    fn rec<R: RoundMode>(t: &[f64], q: &Quantizer) -> f64 {
        match t.len() {
            0 => 0.0,
            1 => t[0],
            n => {
                let (a, b) = t.split_at(n / 2);
                q.quantize_m::<R>(rec::<R>(a, q) + rec::<R>(b, q))
            }
        }
    }
    rec::<R>(terms, q)
}

/// The identity (`man_bits >= 52`) fast path of [`sequential_sum_q`]:
/// quantization is a pass-through, so the sum is the plain left-fold in
/// f64 — the same sequence of additions, hence bit-identical.
#[inline]
fn identity_sum(terms: &[f64]) -> f64 {
    let mut s = 0.0;
    for &p in terms {
        s += p;
    }
    s
}

/// Identity fast path of [`chunked_sum_q`]. The chunk structure still
/// matters (f64 addition is not associative), so the two-level order is
/// preserved; only the per-add quantization disappears.
#[inline]
fn identity_chunked_sum(terms: &[f64], chunk: usize) -> f64 {
    let mut inter = 0.0;
    for block in terms.chunks(chunk) {
        inter += identity_sum(block);
    }
    inter
}

/// Reference oracle for [`sequential_sum`]: the original free-`quantize`
/// implementation, retained verbatim for bit-identity regression tests.
pub fn sequential_sum_ref(terms: &[f64], acc_fmt: FpFormat, mode: Rounding) -> f64 {
    let mut s = 0.0;
    for &p in terms {
        s = quantize(s + p, acc_fmt, mode);
    }
    s
}

/// Reference oracle for [`chunked_sum`]; see [`sequential_sum_ref`].
pub fn chunked_sum_ref(terms: &[f64], chunk: usize, acc_fmt: FpFormat, mode: Rounding) -> f64 {
    assert!(chunk > 0, "chunk size must be positive");
    let mut inter = 0.0;
    for block in terms.chunks(chunk) {
        let intra = sequential_sum_ref(block, acc_fmt, mode);
        inter = quantize(inter + intra, acc_fmt, mode);
    }
    inter
}

/// Reference oracle for [`pairwise_sum`]; see [`sequential_sum_ref`].
pub fn pairwise_sum_ref(terms: &[f64], acc_fmt: FpFormat, mode: Rounding) -> f64 {
    fn rec(t: &[f64], fmt: FpFormat, mode: Rounding) -> f64 {
        match t.len() {
            0 => 0.0,
            1 => t[0],
            n => {
                let (a, b) = t.split_at(n / 2);
                quantize(rec(a, fmt, mode) + rec(b, fmt, mode), fmt, mode)
            }
        }
    }
    rec(terms, acc_fmt, mode)
}

/// Exact (compensated) reference sum — Neumaier's improved Kahan
/// summation; error is O(1) ulps of the result in f64, effectively exact
/// relative to the reduced-precision formats under study.
pub fn exact_sum(terms: &[f64]) -> f64 {
    let mut sum = 0.0;
    let mut comp = 0.0;
    for &x in terms {
        let t = sum + x;
        if sum.abs() >= x.abs() {
            comp += (sum - t) + x;
        } else {
            comp += (x - t) + sum;
        }
        sum = t;
    }
    sum + comp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    const MODE: Rounding = Rounding::NearestEven;

    #[test]
    fn exact_sum_handles_cancellation() {
        let terms = [1e16, 1.0, -1e16];
        assert_eq!(exact_sum(&terms), 1.0);
    }

    #[test]
    fn all_algorithms_agree_in_wide_precision() {
        let mut rng = Pcg64::seeded(8);
        let terms: Vec<f64> = (0..500).map(|_| rng.normal()).collect();
        let wide = FpFormat::new(11, 42); // far wider than needed
        let want = exact_sum(&terms);
        for got in [
            sequential_sum(&terms, wide, MODE),
            chunked_sum(&terms, 64, wide, MODE),
            pairwise_sum(&terms, wide, MODE),
        ] {
            assert!((got - want).abs() < 1e-9 * want.abs().max(1.0));
        }
    }

    /// The fast path vs the retained oracle, bit for bit: every
    /// algorithm, both rounding modes, narrow through identity-wide
    /// formats, chunk sizes that divide n and ones that leave a partial
    /// trailing chunk.
    #[test]
    fn quantizer_sums_match_reference() {
        let mut rng = Pcg64::seeded(53);
        let terms: Vec<f64> = (0..1777).map(|_| rng.normal() * 2.0).collect();
        for fmt in [
            FpFormat::accumulator(4),
            FpFormat::accumulator(8),
            FpFormat::accumulator(14),
            FpFormat::new(11, 52), // identity fast path
        ] {
            for mode in [Rounding::NearestEven, Rounding::TowardZero] {
                assert_eq!(
                    sequential_sum(&terms, fmt, mode).to_bits(),
                    sequential_sum_ref(&terms, fmt, mode).to_bits(),
                    "sequential fmt={fmt:?} mode={mode:?}"
                );
                for chunk in [1usize, 7, 64, 2048] {
                    assert_eq!(
                        chunked_sum(&terms, chunk, fmt, mode).to_bits(),
                        chunked_sum_ref(&terms, chunk, fmt, mode).to_bits(),
                        "chunked fmt={fmt:?} mode={mode:?} chunk={chunk}"
                    );
                }
                assert_eq!(
                    pairwise_sum(&terms, fmt, mode).to_bits(),
                    pairwise_sum_ref(&terms, fmt, mode).to_bits(),
                    "pairwise fmt={fmt:?} mode={mode:?}"
                );
            }
        }
    }

    /// The monomorphized `*_q` entry points (what the MC engine calls
    /// after per-config resolution) agree with the dynamic-mode wrappers.
    #[test]
    fn monomorphized_entry_points_match_wrappers() {
        let mut rng = Pcg64::seeded(54);
        let terms: Vec<f64> = (0..513).map(|_| rng.normal()).collect();
        let fmt = FpFormat::accumulator(7);
        let rne = Quantizer::new(fmt, Rounding::NearestEven);
        let rtz = Quantizer::new(fmt, Rounding::TowardZero);
        assert_eq!(
            sequential_sum_q::<Rne>(&terms, &rne).to_bits(),
            sequential_sum(&terms, fmt, Rounding::NearestEven).to_bits()
        );
        assert_eq!(
            sequential_sum_q::<Rtz>(&terms, &rtz).to_bits(),
            sequential_sum(&terms, fmt, Rounding::TowardZero).to_bits()
        );
        assert_eq!(
            chunked_sum_q::<Rne>(&terms, 32, &rne).to_bits(),
            chunked_sum(&terms, 32, fmt, Rounding::NearestEven).to_bits()
        );
        assert_eq!(
            pairwise_sum_q::<Rtz>(&terms, &rtz).to_bits(),
            pairwise_sum(&terms, fmt, Rounding::TowardZero).to_bits()
        );
    }

    #[test]
    fn sequential_swamps_long_positive_sums() {
        // Summing n ones with m_acc=4: once s reaches 2^5=32, adding 1.0
        // (half the quantum 2.0 at that binade) ties-to-even and stalls.
        let fmt = FpFormat::accumulator(4);
        let terms = vec![1.0; 1000];
        let s = sequential_sum(&terms, fmt, MODE);
        assert!(s < 1000.0, "expected swamping, got {s}");
        // The classic stall point: s = 2^{m_acc+1} + ... bounded well below n.
        assert!(s <= 64.0, "s={s}");
    }

    #[test]
    fn chunking_rescues_the_same_sum() {
        let fmt = FpFormat::accumulator(4);
        let terms = vec![1.0; 1024];
        let seq = sequential_sum(&terms, fmt, MODE);
        let chk = chunked_sum(&terms, 32, fmt, MODE);
        assert!(chk > seq, "chunked {chk} should beat sequential {seq}");
        // 32 chunks of 32 → intra sums are exact (32 = 2^5 with m=4 holds
        // integers to 2^5); inter sum of 32 values of 32.0 is exact too.
        assert_eq!(chk, 1024.0);
    }

    #[test]
    fn chunked_equals_sequential_when_chunk_covers_all() {
        let mut rng = Pcg64::seeded(12);
        let terms: Vec<f64> = (0..256).map(|_| rng.normal()).collect();
        let fmt = FpFormat::accumulator(8);
        // One intra pass + one inter add of the single intra result: the
        // final inter add of (0 + intra) re-quantizes an already
        // representable value, so results match exactly.
        assert_eq!(
            chunked_sum(&terms, 256, fmt, MODE),
            sequential_sum(&terms, fmt, MODE)
        );
    }

    #[test]
    fn accumulator_streaming_matches_batch() {
        let mut rng = Pcg64::seeded(21);
        let terms: Vec<f64> = (0..777).map(|_| rng.normal() * 3.0).collect();
        let arith = RpArith::paper(7);
        let mut acc = Accumulator::new(arith);
        for &t in &terms {
            acc.push(t);
        }
        assert_eq!(
            acc.sum(),
            sequential_sum(&terms, FpFormat::accumulator(7), MODE)
        );
        assert_eq!(acc.count(), 777);
        assert_eq!(acc.arith().acc, FpFormat::accumulator(7));
    }

    #[test]
    fn pairwise_beats_sequential_on_long_sums() {
        let fmt = FpFormat::accumulator(5);
        let terms = vec![1.0; 4096];
        let seq = sequential_sum(&terms, fmt, MODE);
        let pw = pairwise_sum(&terms, fmt, MODE);
        assert!(pw > seq);
    }

    #[test]
    fn truncation_mode_loses_more_than_rne() {
        let fmt = FpFormat::accumulator(6);
        let mut rng = Pcg64::seeded(31);
        // Positive terms make truncation bias visible.
        let terms: Vec<f64> = (0..2000).map(|_| rng.next_f64() + 0.5).collect();
        let want = exact_sum(&terms);
        let rne = sequential_sum(&terms, fmt, Rounding::NearestEven);
        let trunc = sequential_sum(&terms, fmt, Rounding::TowardZero);
        assert!((rne - want).abs() <= (trunc - want).abs());
    }

    #[test]
    fn sums_are_scale_invariant() {
        // Exact binary scaling of every term scales every partial sum
        // exactly — sequential, chunked and pairwise results all scale
        // with it (the simulator-level counterpart of the VRR's
        // σ_p-independence).
        let mut rng = Pcg64::seeded(77);
        let terms: Vec<f64> = (0..1500).map(|_| rng.normal()).collect();
        let scaled: Vec<f64> = terms.iter().map(|t| t * 2f64.powi(5)).collect();
        let fmt = FpFormat::accumulator(6);
        assert_eq!(
            sequential_sum(&scaled, fmt, MODE),
            sequential_sum(&terms, fmt, MODE) * 32.0
        );
        assert_eq!(
            chunked_sum(&scaled, 64, fmt, MODE),
            chunked_sum(&terms, 64, fmt, MODE) * 32.0
        );
        assert_eq!(
            pairwise_sum(&scaled, fmt, MODE),
            pairwise_sum(&terms, fmt, MODE) * 32.0
        );
    }

    #[test]
    fn chunked_is_permutation_sensitive_but_bounded() {
        // Reduced-precision accumulation is order-dependent (that is the
        // whole point), but any order's result stays within the coarse
        // envelope of the exact sum ± n·(worst per-step rounding).
        let mut rng = Pcg64::seeded(31);
        let mut terms: Vec<f64> = (0..4096).map(|_| rng.normal()).collect();
        let fmt = FpFormat::accumulator(8);
        let a = chunked_sum(&terms, 64, fmt, MODE);
        rng.shuffle(&mut terms);
        let b = chunked_sum(&terms, 64, fmt, MODE);
        let exact = exact_sum(&terms);
        // Same ensemble statistics: both orders land in the same ballpark.
        let envelope = 4096.0 * 2f64.powi(-8) * 8.0 + exact.abs();
        assert!((a - exact).abs() < envelope, "a={a} exact={exact}");
        assert!((b - exact).abs() < envelope, "b={b} exact={exact}");
    }

    #[test]
    fn empty_and_singleton() {
        let fmt = FpFormat::accumulator(8);
        assert_eq!(sequential_sum(&[], fmt, MODE), 0.0);
        assert_eq!(chunked_sum(&[], 64, fmt, MODE), 0.0);
        assert_eq!(pairwise_sum(&[], fmt, MODE), 0.0);
        assert_eq!(sequential_sum(&[2.5], fmt, MODE), 2.5);
        assert_eq!(pairwise_sum(&[2.5], fmt, MODE), 2.5);
    }
}
