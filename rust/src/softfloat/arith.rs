//! Reduced-precision arithmetic: multiply and add with rounding to a
//! target format after every operation, exactly as a narrow hardware MAC
//! unit behaves.

use super::format::FpFormat;
use super::quant::{quantize, Rounding};

/// A reduced-precision arithmetic context: the accumulator format, the
/// product format, and the rounding mode.
#[derive(Clone, Copy, Debug)]
pub struct RpArith {
    /// Format of partial sums (the accumulator register).
    pub acc: FpFormat,
    /// Format of the product terms entering the accumulation.
    pub prod: FpFormat,
    pub mode: Rounding,
}

impl RpArith {
    pub fn new(acc: FpFormat, prod: FpFormat) -> Self {
        RpArith {
            acc,
            prod,
            mode: Rounding::NearestEven,
        }
    }

    /// The paper's standard configuration: inputs are (1,5,2) so products
    /// carry `m_p = 5` mantissa bits; accumulator is `(1,6,m_acc)`.
    pub fn paper(m_acc: u32) -> Self {
        RpArith::new(FpFormat::accumulator(m_acc), FpFormat::PROD_FP8)
    }

    /// Multiply two (already representation-quantized) operands and round
    /// the product to the product format.
    ///
    /// For the paper's (1,5,2) inputs the product is *exact* in
    /// `m_p = 2·2+1 = 5` bits, so this rounding is a no-op there — but the
    /// general path matters for ablations with wider inputs.
    #[inline]
    pub fn mul(&self, a: f64, b: f64) -> f64 {
        quantize(a * b, self.prod, self.mode)
    }

    /// Add a product term into the running partial sum, rounding the
    /// result to the accumulator format. This is where swamping happens:
    /// when `|s| >> |p|`, the aligned mantissa bits of `p` fall below the
    /// accumulator quantum and are (partially or fully) lost.
    #[inline]
    pub fn add(&self, s: f64, p: f64) -> f64 {
        quantize(s + p, self.acc, self.mode)
    }

    /// Fused multiply-accumulate as the paper's modified GEMM performs it:
    /// round the product to `m_p`, then round the sum to `m_acc`.
    #[inline]
    pub fn mac(&self, s: f64, a: f64, b: f64) -> f64 {
        self.add(s, self.mul(a, b))
    }
}

/// Does adding `p` into `s` fully swamp `p`? (paper §4 definition (1):
/// `|s| > 2^{m_acc} · |p|` — `p` contributes nothing to the rounded sum.)
pub fn fully_swamps(s: f64, p: f64, m_acc: u32) -> bool {
    p != 0.0 && s.abs() > 2f64.powi(m_acc as i32) * p.abs()
}

/// Does adding `p` into `s` *partially* swamp `p`? (definition (2):
/// `2^{m_acc-m_p}·|p| < |s| ≤ 2^{m_acc}·|p|` — some low-order bits of `p`
/// are shifted out.)
pub fn partially_swamps(s: f64, p: f64, m_acc: u32, m_p: u32) -> bool {
    if p == 0.0 {
        return false;
    }
    let lo = 2f64.powi((m_acc - m_p) as i32) * p.abs();
    let hi = 2f64.powi(m_acc as i32) * p.abs();
    s.abs() > lo && s.abs() <= hi
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn product_of_fp8_operands_is_exact() {
        // Every pair of (1,5,2) values multiplies exactly into (1,6,5).
        let ar = RpArith::paper(12);
        let mantissas = [1.0, 1.25, 1.5, 1.75];
        for &ma in &mantissas {
            for &mb in &mantissas {
                for ea in -3..4 {
                    for eb in -3..4 {
                        let a = ma * 2f64.powi(ea);
                        let b = mb * 2f64.powi(eb);
                        assert_eq!(ar.mul(a, b), a * b);
                    }
                }
            }
        }
    }

    #[test]
    fn full_swamping_drops_small_addend() {
        // m_acc = 4: quantum at |s|=2^10 is 2^6; adding 1.0 (< half
        // quantum) leaves s unchanged.
        let ar = RpArith::new(FpFormat::accumulator(4), FpFormat::PROD_FP8);
        let s = 1024.0;
        assert_eq!(ar.add(s, 1.0), s);
        assert!(fully_swamps(s, 1.0, 4));
    }

    #[test]
    fn partial_swamping_keeps_high_bits() {
        // m_acc = 6, m_p = 5: s = 64.0, p = 1.03125 (= 1 + 2^-5, exact in
        // m_p=5). Quantum at 64 is 2^0 = 1 for m_acc=6... s+p = 65.03125 →
        // rounds to 65.0: the 2^-5 tail is lost (partial swamping), the
        // leading 1 survives.
        let ar = RpArith::new(FpFormat::accumulator(6), FpFormat::PROD_FP8);
        let s = 64.0;
        let p = 1.0 + 2f64.powi(-5);
        let r = ar.add(s, p);
        assert_eq!(r, 65.0);
        assert!(partially_swamps(s, p, 6, 5));
        assert!(!fully_swamps(s, p, 6));
    }

    #[test]
    fn swamping_predicates_partition() {
        // A (s, p) pair cannot be both fully and partially swamping.
        for e in 0..20 {
            let s = 2f64.powi(e);
            let p = 1.0;
            let full = fully_swamps(s, p, 8);
            let part = partially_swamps(s, p, 8, 5);
            assert!(!(full && part), "e={e}");
        }
    }

    #[test]
    fn mac_matches_manual_sequence() {
        let ar = RpArith::paper(8);
        let s = 3.5;
        let (a, b) = (1.25, 1.5);
        assert_eq!(ar.mac(s, a, b), ar.add(s, ar.mul(a, b)));
    }

    #[test]
    fn wide_accumulator_is_transparent_for_small_sums() {
        // With m_acc = 23 and values well inside range, reduced-precision
        // addition agrees with f32-exactness for representable operands.
        let ar = RpArith::new(FpFormat::new(8, 23), FpFormat::new(8, 23));
        assert_eq!(ar.add(0.5, 0.25), 0.75);
        assert_eq!(ar.add(1.0, 2f64.powi(-23)), 1.0 + 2f64.powi(-23));
    }
}
