//! Floating-point format descriptors.
//!
//! A `(1, e, m)` format (paper §2) has one sign bit, `e` exponent bits and
//! `m` mantissa bits. The exponent convention follows IEEE-754: bias
//! `2^{e-1}-1`, all-ones exponent reserved for infinities/NaN, gradual
//! underflow (subnormals) below `E_min = 2 - bias`.

/// A custom floating-point format `(1, e, m)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct FpFormat {
    /// Exponent bits.
    pub exp_bits: u32,
    /// Mantissa (fraction) bits, excluding the hidden leading one.
    pub man_bits: u32,
}

impl FpFormat {
    pub const fn new(exp_bits: u32, man_bits: u32) -> Self {
        FpFormat { exp_bits, man_bits }
    }

    /// IEEE binary32.
    pub const FP32: FpFormat = FpFormat::new(8, 23);
    /// IEEE binary16.
    pub const FP16: FpFormat = FpFormat::new(5, 10);
    /// bfloat16.
    pub const BF16: FpFormat = FpFormat::new(8, 7);
    /// The paper's representation format for weights/activations/gradients:
    /// (1,5,2) — Wang et al. (2018) FP8.
    pub const FP8_152: FpFormat = FpFormat::new(5, 2);
    /// (1,6,5): the exact product of two (1,5,2) values (mantissa
    /// `1.m × 1.m` needs 2+2+1 = 5 bits; exponent range doubles).
    pub const PROD_FP8: FpFormat = FpFormat::new(6, 5);

    /// The paper's accumulator format: 6 exponent bits (§5: "we use 6-b of
    /// exponents in the accumulations") and a swept mantissa width.
    pub const fn accumulator(man_bits: u32) -> FpFormat {
        FpFormat::new(6, man_bits)
    }

    /// Total storage width `1 + e + m`.
    pub const fn bits(&self) -> u32 {
        1 + self.exp_bits + self.man_bits
    }

    /// Exponent bias `2^{e-1} - 1`.
    pub const fn bias(&self) -> i32 {
        (1 << (self.exp_bits - 1)) - 1
    }

    /// Largest unbiased exponent of a normal number (all-ones reserved).
    pub const fn e_max(&self) -> i32 {
        self.bias()
    }

    /// Smallest unbiased exponent of a normal number.
    pub const fn e_min(&self) -> i32 {
        1 - self.bias()
    }

    /// Largest finite value: `(2 - 2^-m) · 2^{e_max}`.
    ///
    /// Constructed directly from bits (exponent field `e_max`, top `m`
    /// mantissa bits set) — this sits on the `quantize` hot path.
    pub fn max_finite(&self) -> f64 {
        if self.man_bits >= 52 {
            // Wide "ideal" simulation formats: effectively unbounded.
            return f64::MAX;
        }
        let e_field = (self.e_max() + 1023) as u64;
        let mant = ((1u64 << self.man_bits) - 1) << (52 - self.man_bits);
        f64::from_bits((e_field << 52) | mant)
    }

    /// Smallest positive normal value `2^{e_min}`.
    pub fn min_normal(&self) -> f64 {
        2f64.powi(self.e_min())
    }

    /// Smallest positive subnormal value `2^{e_min - m}`.
    pub fn min_subnormal(&self) -> f64 {
        2f64.powi(self.e_min() - self.man_bits as i32)
    }

    /// Unit roundoff `2^{-(m+1)}` (half ulp of 1.0).
    pub fn unit_roundoff(&self) -> f64 {
        (0.5f64).powi(self.man_bits as i32 + 1)
    }

    /// Human-readable `(1,e,m)` notation used throughout the paper.
    pub fn notation(&self) -> String {
        format!("(1,{},{})", self.exp_bits, self.man_bits)
    }
}

impl std::fmt::Display for FpFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.notation())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp32_constants_match_ieee() {
        let f = FpFormat::FP32;
        assert_eq!(f.bits(), 32);
        assert_eq!(f.bias(), 127);
        assert_eq!(f.e_max(), 127);
        assert_eq!(f.e_min(), -126);
        assert_eq!(f.max_finite(), f32::MAX as f64);
        assert_eq!(f.min_normal(), f32::MIN_POSITIVE as f64);
    }

    #[test]
    fn fp16_constants_match_ieee() {
        let f = FpFormat::FP16;
        assert_eq!(f.bits(), 16);
        assert_eq!(f.bias(), 15);
        assert_eq!(f.max_finite(), 65504.0);
        assert_eq!(f.min_normal(), 2f64.powi(-14));
        assert_eq!(f.min_subnormal(), 2f64.powi(-24));
    }

    #[test]
    fn fp8_152_shape() {
        let f = FpFormat::FP8_152;
        assert_eq!(f.bits(), 8);
        assert_eq!(f.bias(), 15);
        // max = 1.75 * 2^15 = 57344
        assert_eq!(f.max_finite(), 57344.0);
    }

    #[test]
    fn accumulator_uses_six_exponent_bits() {
        let f = FpFormat::accumulator(12);
        assert_eq!(f.exp_bits, 6);
        assert_eq!(f.man_bits, 12);
        assert_eq!(f.bias(), 31);
    }

    #[test]
    fn notation_formats() {
        assert_eq!(FpFormat::FP8_152.notation(), "(1,5,2)");
        assert_eq!(FpFormat::accumulator(9).to_string(), "(1,6,9)");
    }
}
