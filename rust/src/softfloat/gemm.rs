//! Reduced-precision GEMM — the software model of the paper's modified
//! CUDA GEMM: inputs quantized to the representation format (1,5,2),
//! products formed exactly in `m_p = 5` bits, and every partial sum
//! rounded to the `(1,6,m_acc)` accumulator format, optionally with
//! two-level chunked accumulation.
//!
//! Two implementations share one semantics:
//!
//! * [`rp_gemm_ref`] — the scalar reference: quantize both operands,
//!   materialize the product terms of each dot, run the accumulation
//!   algorithms from [`super::accumulate`]. Slow, obviously correct,
//!   and the oracle the kernel is pinned against.
//! * the **kernel** ([`rp_gemm`] / [`rp_gemm_ex`] / [`rp_gemm_packed`])
//!   — row-panel parallel over the persistent [`crate::runtime::pool`],
//!   with a fused quantize-MAC inner loop monomorphized per
//!   `(Rounding, chunked?)` and format constants precomputed in
//!   [`Quantizer`]s. Because every output element is an independent
//!   reduced-precision dot product, the result is **bit-identical at
//!   any thread count** and to the reference (asserted across layouts,
//!   modes and thread counts in `tests/gemm.rs` and the CI hash smoke).
//!
//! [`rp_gemm_ex`] additionally takes a [`Layout`] flag (NN/NT/TN) so
//! callers with transposed access patterns (the trainer's `dW = Xᵀ·dY`)
//! stop materializing `.t()` copies, and a [`GemmCtx`] carrying the
//! thread count and a cooperative deadline that is checked between row
//! panels — a long GEMM inside a served train request cancels mid-flight
//! instead of running to completion. See `docs/gemm.md`.

use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use super::accumulate::{chunked_sum, sequential_sum};
use super::arith::RpArith;
use super::format::FpFormat;
use super::quant::{quantize, Quantizer, Rne, RoundMode, Rounding, Rtz};
use super::tensor::Tensor;
use crate::coordinator::sweep::default_threads;
use crate::runtime::pool;
use crate::telemetry;
use crate::telemetry::{health, trace};

/// Configuration of a reduced-precision GEMM.
#[derive(Clone, Copy, Debug)]
pub struct GemmConfig {
    /// Representation format applied to the *inputs* (None = keep f32).
    pub repr: Option<FpFormat>,
    /// Product-term format (`m_p`).
    pub prod: FpFormat,
    /// Accumulator format (`m_acc`).
    pub acc: FpFormat,
    /// Chunk size for two-level accumulation; `None` = plain sequential.
    pub chunk: Option<usize>,
    pub mode: Rounding,
}

impl GemmConfig {
    /// Paper configuration: (1,5,2) inputs, exact 5-bit products,
    /// `(1,6,m_acc)` partial sums, optional chunk-64 accumulation.
    pub fn paper(m_acc: u32, chunk: Option<usize>) -> GemmConfig {
        GemmConfig {
            repr: Some(FpFormat::FP8_152),
            prod: FpFormat::PROD_FP8,
            acc: FpFormat::accumulator(m_acc),
            chunk,
            mode: Rounding::NearestEven,
        }
    }

    /// Full-precision baseline (no quantization anywhere) — the paper's
    /// "accumulation in full precision" control arm.
    pub fn baseline() -> GemmConfig {
        GemmConfig {
            repr: None,
            prod: FpFormat::new(11, 52),
            acc: FpFormat::new(11, 52),
            chunk: None,
            mode: Rounding::NearestEven,
        }
    }

    pub fn arith(&self) -> RpArith {
        RpArith {
            acc: self.acc,
            prod: self.prod,
            mode: self.mode,
        }
    }
}

/// Operand layout of `C = op(A)·op(B)`: which sides arrive transposed.
/// Lets callers keep operands in natural storage instead of
/// materializing `.t()` copies; the transpose is folded into the packing
/// step of the kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Layout {
    /// `C = A·B` — `A: [m,k]`, `B: [k,n]`.
    #[default]
    NN,
    /// `C = A·Bᵀ` — `A: [m,k]`, `B: [n,k]`.
    NT,
    /// `C = Aᵀ·B` — `A: [k,m]`, `B: [k,n]`.
    TN,
}

/// Execution context of one GEMM call: parallelism and cooperative
/// cancellation. The default (`threads: 0`, no deadline) means one
/// participant per available core — the repo-wide convention shared
/// with `coordinator::sweep::default_threads` and the serve pool.
#[derive(Clone, Copy, Debug, Default)]
pub struct GemmCtx {
    /// Participants (caller + pool workers); `0` = one per core. Any
    /// value yields bit-identical output.
    pub threads: usize,
    /// Checked between row panels; once passed, the GEMM stops claiming
    /// panels and returns [`Interrupted`].
    pub deadline: Option<Instant>,
    /// Label for this GEMM in trace spans and health-monitor series
    /// (the trainer passes `"fwd"`/`"bwd"`/`"grad"`); `""` falls back
    /// to `"gemm"`.
    pub op: &'static str,
}

/// A GEMM stopped cooperatively because its [`GemmCtx::deadline`]
/// passed. The partially written output is discarded — no partial
/// result escapes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interrupted;

impl std::fmt::Display for Interrupted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("GEMM interrupted by its deadline")
    }
}

impl std::error::Error for Interrupted {}

/// A rank-2 operand pre-quantized to a representation format, carrying
/// row-major data plus a lazily built column-major copy — one
/// quantization pass serves every GEMM that needs either orientation of
/// the operand (e.g. the trainer's `W2`, read column-wise by FWD and
/// row-wise by BWD in the same step). The `(repr, mode)` key records
/// what the data was quantized under; [`QuantizedOperand::matches`] is
/// the cache-validity check. Invalidation is the *owner's* job: any
/// mutation of the source tensor (an SGD weight update) must drop the
/// packed operand (see `docs/gemm.md`).
pub struct QuantizedOperand {
    rows: usize,
    cols: usize,
    key: Option<(FpFormat, Rounding)>,
    row_major: Vec<f32>,
    col_major: OnceLock<Vec<f32>>,
}

impl QuantizedOperand {
    /// Quantize rank-2 `t` under `repr`/`mode` (`repr = None` keeps f32).
    pub fn new(t: &Tensor, repr: Option<FpFormat>, mode: Rounding) -> QuantizedOperand {
        assert_eq!(t.rank(), 2);
        let row_major = match repr {
            Some(fmt) => {
                let q = Quantizer::new(fmt, mode);
                t.data.iter().map(|&x| q.quantize(x as f64) as f32).collect()
            }
            None => t.data.clone(),
        };
        QuantizedOperand {
            rows: t.shape[0],
            cols: t.shape[1],
            key: repr.map(|f| (f, mode)),
            row_major,
            col_major: OnceLock::new(),
        }
    }

    /// Pack `t` for the GEMM config `cfg` (its `repr` and `mode`).
    pub fn for_cfg(t: &Tensor, cfg: &GemmConfig) -> QuantizedOperand {
        QuantizedOperand::new(t, cfg.repr, cfg.mode)
    }

    /// The `(repr, mode)` key a config would quantize operands under.
    pub fn key_of(cfg: &GemmConfig) -> Option<(FpFormat, Rounding)> {
        cfg.repr.map(|f| (f, cfg.mode))
    }

    /// Is this packed operand valid for `cfg` (same repr format and
    /// rounding mode)? `false` means the caller must re-pack.
    pub fn matches(&self, cfg: &GemmConfig) -> bool {
        self.key == Self::key_of(cfg)
    }

    /// Source shape `(rows, cols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn row_view(&self) -> &[f32] {
        &self.row_major
    }

    /// Column-major copy (the transpose), built once on first use from
    /// the already-quantized data — the transpose is never re-quantized
    /// (quantization is elementwise, so the two commute).
    fn col_view(&self) -> &[f32] {
        self.col_major.get_or_init(|| {
            let (r, c) = (self.rows, self.cols);
            let mut out = vec![0.0f32; r * c];
            for i in 0..r {
                for j in 0..c {
                    out[j * r + i] = self.row_major[i * c + j];
                }
            }
            out
        })
    }
}

/// One reduced-precision dot product over pre-quantized operand slices.
///
/// `a` strided by `sa`, `b` strided by `sb`, length `k`. Products are
/// rounded to `cfg.prod`, partial sums to `cfg.acc` (sequential or
/// chunked). This is the exact inner loop the VRR analysis models, and
/// the documented reference form of the kernel's fused quantize-MAC
/// loop — same [`Quantizer`] ops in the same order, no intermediate
/// product buffer (it used to allocate a `Vec` per call).
pub fn rp_dot(a: &[f32], sa: usize, b: &[f32], sb: usize, k: usize, cfg: &GemmConfig) -> f64 {
    let prod_q = Quantizer::new(cfg.prod, cfg.mode);
    let acc_q = Quantizer::new(cfg.acc, cfg.mode);
    match cfg.chunk {
        None => {
            let mut s = 0.0f64;
            for l in 0..k {
                let p = prod_q.quantize(a[l * sa] as f64 * b[l * sb] as f64);
                s = acc_q.quantize(s + p);
            }
            s
        }
        Some(c) => {
            assert!(c > 0, "chunk size must be positive");
            let mut inter = 0.0f64;
            let mut l = 0;
            while l < k {
                let end = (l + c).min(k);
                let mut intra = 0.0f64;
                for i in l..end {
                    let p = prod_q.quantize(a[i * sa] as f64 * b[i * sb] as f64);
                    intra = acc_q.quantize(intra + p);
                }
                inter = acc_q.quantize(inter + intra);
                l = end;
            }
            inter
        }
    }
}

/// Reduced-precision GEMM, `C = A·B`, `A: [m,k]`, `B: [k,n]`.
///
/// Inputs are first quantized to the representation format (if any); each
/// output element is an independent length-`k` reduced-precision
/// accumulation — matching how a systolic/SIMT GEMM partitions work, and
/// matching Assumption 1's per-dot-product view. Runs the parallel
/// kernel with default context (one participant per core, no deadline).
pub fn rp_gemm(a: &Tensor, b: &Tensor, cfg: &GemmConfig) -> Tensor {
    rp_gemm_ex(a, b, cfg, Layout::NN, &GemmCtx::default())
        .expect("rp_gemm: no deadline in the default context")
}

/// Layout-aware reduced-precision GEMM: `C = op(A)·op(B)` per `layout`,
/// executed under `ctx` (thread count, cooperative deadline). Operands
/// are representation-quantized once here; use [`rp_gemm_packed`] to
/// reuse a [`QuantizedOperand`] across calls.
pub fn rp_gemm_ex(
    a: &Tensor,
    b: &Tensor,
    cfg: &GemmConfig,
    layout: Layout,
    ctx: &GemmCtx,
) -> Result<Tensor, Interrupted> {
    let aq = QuantizedOperand::for_cfg(a, cfg);
    let bq = QuantizedOperand::for_cfg(b, cfg);
    rp_gemm_packed(&aq, &bq, cfg, layout, ctx)
}

/// Layout-aware reduced-precision GEMM over pre-packed operands. The
/// operands must have been packed under `cfg`'s `(repr, mode)` key —
/// checked in debug builds; see [`QuantizedOperand::matches`].
pub fn rp_gemm_packed(
    a: &QuantizedOperand,
    b: &QuantizedOperand,
    cfg: &GemmConfig,
    layout: Layout,
    ctx: &GemmCtx,
) -> Result<Tensor, Interrupted> {
    debug_assert!(
        a.matches(cfg) && b.matches(cfg),
        "operand packed under a different (repr, mode) key than the GEMM config"
    );
    // The kernel wants rows of op(A) and *columns* of op(B) contiguous;
    // both views are length-k panels, so `b_view` is op(B)ᵀ as [n,k].
    let ((m, k), a_view) = match layout {
        Layout::NN | Layout::NT => (a.shape(), a.row_view()),
        Layout::TN => {
            let (k, m) = a.shape();
            ((m, k), a.col_view())
        }
    };
    let ((kb, n), b_view) = match layout {
        Layout::NN | Layout::TN => {
            let (kb, n) = b.shape();
            ((kb, n), b.col_view())
        }
        Layout::NT => {
            let (n, kb) = b.shape();
            ((kb, n), b.row_view())
        }
    };
    assert_eq!(k, kb, "inner dims mismatch: {k} vs {kb}");
    run_panels(a_view, b_view, m, n, k, cfg, ctx)
}

/// Output pointer shared across pool participants. Sound: participants
/// claim disjoint row-panel ranges from an atomic index, so no two
/// threads ever touch the same element.
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Telemetry handles, resolved once per process (not per GEMM).
type GemmTel = (
    Arc<telemetry::Counter>,
    Arc<telemetry::Histogram>,
    Arc<telemetry::Histogram>,
);

fn gemm_tel() -> &'static GemmTel {
    static TEL: OnceLock<GemmTel> = OnceLock::new();
    TEL.get_or_init(|| {
        (
            telemetry::counter("abws_gemm_macs_total"),
            telemetry::histogram("abws_gemm_wall_ns"),
            telemetry::histogram("abws_gemm_worker_utilization_pct"),
        )
    })
}

/// The packed kernel: `a` holds the m rows of op(A), `b` the n columns
/// of op(B) (each a contiguous length-`k` panel). Row panels of the
/// output are claimed from an atomic index by every pool participant;
/// the deadline is polled once per claimed panel.
fn run_panels(
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    cfg: &GemmConfig,
    ctx: &GemmCtx,
) -> Result<Tensor, Interrupted> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    let mut out = Tensor::zeros(&[m, n]);
    if m == 0 || n == 0 {
        return Ok(out);
    }
    if let Some(c) = cfg.chunk {
        assert!(c > 0, "chunk size must be positive");
    }

    let threads = if ctx.threads == 0 {
        default_threads()
    } else {
        ctx.threads
    };
    let threads = threads.clamp(1, m);
    // ~4 panels per participant: enough slack for load balancing and for
    // deadline polls, few enough that claim traffic stays negligible.
    let panel = m.div_ceil(threads * 4).max(1);

    let op = if ctx.op.is_empty() { "gemm" } else { ctx.op };
    // Parent span for this GEMM; the pool captures it as the region
    // context, so every participant's `pool.region` (and the `gemm.panel`
    // spans inside) attaches below it.
    let _gspan = if trace::enabled() {
        trace::TraceSpan::enter("gemm")
            .attr("op", op)
            .attr("shape", format!("{m}x{k}x{n}"))
            .attr("m_acc", cfg.acc.man_bits.to_string())
            .attr(
                "chunk",
                cfg.chunk.map_or_else(|| "none".into(), |c| c.to_string()),
            )
    } else {
        trace::TraceSpan::noop()
    };

    let kern = Kern {
        a,
        b,
        n,
        k,
        prod: Quantizer::new(cfg.prod, cfg.mode),
        acc: Quantizer::new(cfg.acc, cfg.mode),
        mode: cfg.mode,
        chunk: cfg.chunk,
    };

    let next = AtomicUsize::new(0);
    let cancelled = AtomicBool::new(false);
    let out_ptr = SendPtr(out.data.as_mut_ptr());
    let deadline = ctx.deadline;

    let job = || {
        loop {
            if cancelled.load(Ordering::Relaxed) {
                break;
            }
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    cancelled.store(true, Ordering::Relaxed);
                    break;
                }
            }
            let start = next.fetch_add(panel, Ordering::Relaxed);
            if start >= m {
                break;
            }
            let end = (start + panel).min(m);
            let _pspan = if trace::enabled() {
                trace::TraceSpan::enter("gemm.panel").attr("rows", format!("{start}..{end}"))
            } else {
                trace::TraceSpan::noop()
            };
            // Disjoint rows `start..end` of the output — exclusively
            // ours for this panel (see `SendPtr`).
            let out_rows = unsafe {
                std::slice::from_raw_parts_mut(out_ptr.0.add(start * n), (end - start) * n)
            };
            kern.run(start..end, out_rows);
        }
    };
    let report = pool::run(threads, &job);

    if telemetry::enabled() {
        let (macs, wall_ns, util_pct) = gemm_tel();
        wall_ns.record(report.wall_ns);
        for pct in report.utilization_pct() {
            util_pct.record(pct);
        }
        if !cancelled.load(Ordering::Relaxed) {
            macs.add((m * n * k) as u64);
        }
    }
    if cancelled.load(Ordering::Relaxed) {
        return Err(Interrupted);
    }

    // Numerics health, 1-in-K GEMM calls: re-derive the product terms of
    // one dot and replay them instrumented (swamping count, exact sum).
    // The kernel's output is never touched — purely an observer.
    if health::should_sample() {
        let t = health::sample_tick() as usize;
        let (i, j) = (t % m, t % n);
        let terms: Vec<f64> = a[i * k..(i + 1) * k]
            .iter()
            .zip(&b[j * k..(j + 1) * k])
            .map(|(&x, &y)| kern.prod.quantize(x as f64 * y as f64))
            .collect();
        health::observe(op, &terms, cfg.acc, cfg.mode, Some(cfg.prod.man_bits), cfg.chunk);
    }
    Ok(out)
}

/// The monomorphized fused quantize-MAC kernel over a row range.
struct Kern<'a> {
    /// Rows of op(A): m contiguous length-k panels.
    a: &'a [f32],
    /// Columns of op(B): n contiguous length-k panels.
    b: &'a [f32],
    n: usize,
    k: usize,
    prod: Quantizer,
    acc: Quantizer,
    mode: Rounding,
    chunk: Option<usize>,
}

impl Kern<'_> {
    /// Compute output rows `rows` into `out` (`rows.len() * n` floats).
    /// Resolves the `(mode, chunked?)` monomorphization and the
    /// both-formats-identity fast path once per panel — never per
    /// element.
    fn run(&self, rows: Range<usize>, out: &mut [f32]) {
        if self.prod.is_identity() && self.acc.is_identity() && self.chunk.is_none() {
            return self.rows_identity(rows, out);
        }
        match (self.mode, self.chunk.is_some()) {
            (Rounding::NearestEven, false) => self.rows_fused::<Rne, false>(rows, out),
            (Rounding::NearestEven, true) => self.rows_fused::<Rne, true>(rows, out),
            (Rounding::TowardZero, false) => self.rows_fused::<Rtz, false>(rows, out),
            (Rounding::TowardZero, true) => self.rows_fused::<Rtz, true>(rows, out),
        }
    }

    /// Both formats at least f64-wide and sequential accumulation: every
    /// quantization is the identity, so the dot is a plain f64 sum in
    /// the same association order — bit-identical to the fused path,
    /// minus all per-element branching. (Chunked identity configs still
    /// take the fused path: chunking changes the association order even
    /// when rounding is the identity.)
    fn rows_identity(&self, rows: Range<usize>, out: &mut [f32]) {
        let (n, k) = (self.n, self.k);
        for (oi, i) in rows.enumerate() {
            let arow = &self.a[i * k..(i + 1) * k];
            for j in 0..n {
                let bcol = &self.b[j * k..(j + 1) * k];
                let mut s = 0.0f64;
                for (&x, &y) in arow.iter().zip(bcol) {
                    s += x as f64 * y as f64;
                }
                out[oi * n + j] = s as f32;
            }
        }
    }

    /// The fused quantize-MAC loop: product rounding and partial-sum
    /// rounding inline per MAC, no intermediate product buffer, format
    /// constants precomputed in the [`Quantizer`]s, rounding mode
    /// monomorphized via `R`. Matches the reference
    /// `quantize`-then-`sequential_sum`/`chunked_sum` composition
    /// bit-for-bit (same operations, same order).
    fn rows_fused<R: RoundMode, const CHUNKED: bool>(&self, rows: Range<usize>, out: &mut [f32]) {
        let (n, k) = (self.n, self.k);
        let (prod, acc) = (self.prod, self.acc);
        let chunk = if CHUNKED { self.chunk.unwrap_or(1) } else { 1 };
        for (oi, i) in rows.enumerate() {
            let arow = &self.a[i * k..(i + 1) * k];
            for j in 0..n {
                let bcol = &self.b[j * k..(j + 1) * k];
                let s = if CHUNKED {
                    let mut inter = 0.0f64;
                    for (ab, bb) in arow.chunks(chunk).zip(bcol.chunks(chunk)) {
                        let mut intra = 0.0f64;
                        for (&x, &y) in ab.iter().zip(bb) {
                            let p = prod.quantize_m::<R>(x as f64 * y as f64);
                            intra = acc.quantize_m::<R>(intra + p);
                        }
                        inter = acc.quantize_m::<R>(inter + intra);
                    }
                    inter
                } else {
                    let mut s = 0.0f64;
                    for (&x, &y) in arow.iter().zip(bcol) {
                        let p = prod.quantize_m::<R>(x as f64 * y as f64);
                        s = acc.quantize_m::<R>(s + p);
                    }
                    s
                };
                out[oi * n + j] = s as f32;
            }
        }
    }
}

/// Scalar reference GEMM — the original implementation, retained
/// verbatim as the oracle for the kernel's bit-identity suite
/// (`tests/gemm.rs`): quantize the operands, materialize each dot's
/// product terms, then run the accumulation algorithms from
/// [`super::accumulate`].
pub fn rp_gemm_ref(a: &Tensor, b: &Tensor, cfg: &GemmConfig) -> Tensor {
    assert_eq!(a.rank(), 2);
    assert_eq!(b.rank(), 2);
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "inner dims mismatch: {k} vs {k2}");

    // Representation quantization of the operands (the paper's (1,5,2)).
    let (aq, bq);
    let (a, b) = match cfg.repr {
        Some(fmt) => {
            aq = a.map(|x| quantize(x as f64, fmt, cfg.mode) as f32);
            bq = b.map(|x| quantize(x as f64, fmt, cfg.mode) as f32);
            (&aq, &bq)
        }
        None => (a, b),
    };

    let mut out = Tensor::zeros(&[m, n]);
    // One scratch buffer for the product terms of every dot, and a
    // transposed copy of B for contiguous column access.
    let bt = b.t();
    let mut prods = vec![0.0f64; k];
    for i in 0..m {
        let row = &a.data[i * k..(i + 1) * k];
        for j in 0..n {
            let col = &bt.data[j * k..(j + 1) * k];
            for l in 0..k {
                prods[l] = quantize(row[l] as f64 * col[l] as f64, cfg.prod, cfg.mode);
            }
            let s = match cfg.chunk {
                Some(c) => chunked_sum(&prods, c, cfg.acc, cfg.mode),
                None => sequential_sum(&prods, cfg.acc, cfg.mode),
            };
            out.data[i * n + j] = s as f32;
        }
    }
    out
}

/// MXU-style chunked dot product — the exact semantics of the Pallas
/// kernel (python/compile/kernels/rp_gemm.py): each chunk's partial sum
/// is computed *exactly* (the hardware chunk adder tree / MXU pass),
/// rounded once to the accumulator format, and folded into a running
/// accumulator that is re-rounded after every chunk. Inputs are
/// representation-quantized to (1,5,2) first when `repr` is set.
///
/// This is the function the cross-language artifact test pins against
/// the executed HLO (rust/tests/aot_runtime.rs).
pub fn rp_dot_mxu(a: &[f32], b_col: &[f32], cfg: &GemmConfig, chunk: usize) -> f64 {
    assert_eq!(a.len(), b_col.len());
    let quant_in = |x: f32| match cfg.repr {
        Some(fmt) => quantize(x as f64, fmt, cfg.mode),
        None => x as f64,
    };
    let mut acc = 0.0f64;
    for block in a.chunks(chunk).zip(b_col.chunks(chunk)) {
        let (ab, bb) = block;
        // Exact intra-chunk sum of exact products (f64 holds both).
        let mut s = 0.0f64;
        for (&x, &y) in ab.iter().zip(bb) {
            s += quant_in(x) * quant_in(y);
        }
        let s = quantize(s, cfg.acc, cfg.mode);
        acc = quantize(acc + s, cfg.acc, cfg.mode);
    }
    acc
}

/// MXU-style reduced-precision GEMM (the Pallas kernel's semantics).
pub fn rp_gemm_mxu(a: &Tensor, b: &Tensor, cfg: &GemmConfig, chunk: usize) -> Tensor {
    let (m, k) = (a.shape[0], a.shape[1]);
    let n = b.shape[1];
    assert_eq!(k, b.shape[0]);
    let bt = b.t();
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        let row = &a.data[i * k..(i + 1) * k];
        for j in 0..n {
            let col = &bt.data[j * k..(j + 1) * k];
            out.data[i * n + j] = rp_dot_mxu(row, col, cfg, chunk) as f32;
        }
    }
    out
}

/// Measured fraction of non-zero product terms in `A·B` — the empirical
/// NZR (paper §4.3) for a GEMM's accumulations.
pub fn gemm_nzr(a: &Tensor, b: &Tensor) -> f64 {
    let (m, k) = (a.shape[0], a.shape[1]);
    let n = b.shape[1];
    let mut nonzero = 0usize;
    let mut total = 0usize;
    for i in 0..m {
        for j in 0..n {
            for l in 0..k {
                total += 1;
                if a.data[i * k + l] != 0.0 && b.data[l * n + j] != 0.0 {
                    nonzero += 1;
                }
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        nonzero as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;
    use std::time::Duration;

    fn bits(t: &Tensor) -> Vec<u32> {
        t.data.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn baseline_matches_f64_matmul() {
        let mut rng = Pcg64::seeded(1);
        let a = Tensor::randn(&[5, 32], 1.0, &mut rng);
        let b = Tensor::randn(&[32, 4], 1.0, &mut rng);
        let c = rp_gemm(&a, &b, &GemmConfig::baseline());
        let want = a.matmul(&b);
        for (x, y) in c.data.iter().zip(&want.data) {
            assert!((x - y).abs() <= 1e-5 * y.abs().max(1.0), "{x} vs {y}");
        }
    }

    #[test]
    fn kernel_matches_scalar_reference_bitwise() {
        let mut rng = Pcg64::seeded(14);
        let a = Tensor::randn(&[7, 129], 1.0, &mut rng);
        let b = Tensor::randn(&[129, 5], 1.0, &mut rng);
        for cfg in [
            GemmConfig::paper(6, None),
            GemmConfig::paper(6, Some(32)),
            GemmConfig::baseline(),
        ] {
            let want = bits(&rp_gemm_ref(&a, &b, &cfg));
            for threads in [1usize, 2, 4] {
                let ctx = GemmCtx {
                    threads,
                    ..GemmCtx::default()
                };
                let got = rp_gemm_ex(&a, &b, &cfg, Layout::NN, &ctx).unwrap();
                assert_eq!(bits(&got), want, "threads={threads} cfg={cfg:?}");
            }
        }
    }

    #[test]
    fn layouts_match_materialized_transposes() {
        let mut rng = Pcg64::seeded(15);
        let a = Tensor::randn(&[4, 33], 1.0, &mut rng);
        let b = Tensor::randn(&[33, 6], 1.0, &mut rng);
        let cfg = GemmConfig::paper(8, Some(16));
        let ctx = GemmCtx::default();
        let want = bits(&rp_gemm_ref(&a, &b, &cfg));
        // NT: pass Bᵀ with the NT flag instead of materializing B.
        let b_nt = b.t();
        let got_nt = rp_gemm_ex(&a, &b_nt, &cfg, Layout::NT, &ctx).unwrap();
        assert_eq!(bits(&got_nt), want);
        // TN: pass Aᵀ with the TN flag.
        let a_tn = a.t();
        let got_tn = rp_gemm_ex(&a_tn, &b, &cfg, Layout::TN, &ctx).unwrap();
        assert_eq!(bits(&got_tn), want);
    }

    #[test]
    fn packed_operands_reuse_one_quantization() {
        let mut rng = Pcg64::seeded(16);
        let x = Tensor::randn(&[6, 40], 1.0, &mut rng);
        let w = Tensor::randn(&[40, 3], 1.0, &mut rng);
        let cfg = GemmConfig::paper(9, None);
        let xq = QuantizedOperand::for_cfg(&x, &cfg);
        let wq = QuantizedOperand::for_cfg(&w, &cfg);
        assert!(xq.matches(&cfg) && wq.matches(&cfg));
        let ctx = GemmCtx::default();
        let via_packed = rp_gemm_packed(&xq, &wq, &cfg, Layout::NN, &ctx).unwrap();
        assert_eq!(bits(&via_packed), bits(&rp_gemm(&x, &w, &cfg)));
        // A different key invalidates the pack.
        let other = GemmConfig::paper(9, None);
        let other = GemmConfig {
            mode: Rounding::TowardZero,
            ..other
        };
        assert!(!xq.matches(&other));
    }

    #[test]
    fn expired_deadline_interrupts() {
        let mut rng = Pcg64::seeded(17);
        let a = Tensor::randn(&[8, 64], 1.0, &mut rng);
        let b = Tensor::randn(&[64, 8], 1.0, &mut rng);
        let ctx = GemmCtx {
            threads: 2,
            deadline: Some(Instant::now() - Duration::from_millis(1)),
            ..GemmCtx::default()
        };
        let r = rp_gemm_ex(&a, &b, &GemmConfig::paper(8, None), Layout::NN, &ctx);
        assert_eq!(r.err(), Some(Interrupted));
    }

    #[test]
    fn degenerate_shapes() {
        // k = 0: every dot is the empty accumulation (exactly 0.0).
        let a = Tensor::zeros(&[3, 0]);
        let b = Tensor::zeros(&[0, 2]);
        let out = rp_gemm(&a, &b, &GemmConfig::paper(8, Some(64)));
        assert_eq!(out.shape, vec![3, 2]);
        assert!(out.data.iter().all(|&x| x == 0.0));
        // 1×1: a single quantized product.
        let a = Tensor::from_vec(&[1, 1], vec![0.3]);
        let b = Tensor::from_vec(&[1, 1], vec![0.7]);
        let cfg = GemmConfig::paper(8, None);
        let out = rp_gemm(&a, &b, &cfg);
        assert_eq!(bits(&out), bits(&rp_gemm_ref(&a, &b, &cfg)));
    }

    #[test]
    fn wide_accumulator_close_to_baseline() {
        let mut rng = Pcg64::seeded(2);
        let a = Tensor::randn(&[4, 256], 0.2, &mut rng);
        let b = Tensor::randn(&[256, 4], 0.2, &mut rng);
        // m_acc=23 is "wide" for n=256 — only representation error remains.
        let c = rp_gemm(&a, &b, &GemmConfig::paper(23, None));
        let mut cfg8 = GemmConfig::paper(23, None);
        cfg8.acc = FpFormat::new(11, 52); // ideal accumulator, same repr
        let want = rp_gemm(&a, &b, &cfg8);
        for (x, y) in c.data.iter().zip(&want.data) {
            assert!((x - y).abs() <= 2e-2 * y.abs().max(0.5), "{x} vs {y}");
        }
    }

    #[test]
    fn narrow_accumulator_loses_variance_on_long_dots() {
        // The headline effect: long accumulation + small m_acc shrinks the
        // output ensemble variance (paper §3).
        let mut rng = Pcg64::seeded(3);
        let k = 8192;
        let a = Tensor::randn(&[8, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, 8], 1.0, &mut rng);
        let ideal = rp_gemm(&a, &b, &{
            let mut c = GemmConfig::paper(30, None);
            c.acc = FpFormat::new(11, 52);
            c
        });
        let narrow = rp_gemm(&a, &b, &GemmConfig::paper(4, None));
        let vi = ideal.variance();
        let vn = narrow.variance();
        assert!(
            vn < 0.8 * vi,
            "expected variance loss: narrow {vn} vs ideal {vi}"
        );
    }

    #[test]
    fn chunking_recovers_variance() {
        let mut rng = Pcg64::seeded(4);
        let k = 8192;
        let a = Tensor::randn(&[8, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, 8], 1.0, &mut rng);
        let narrow = rp_gemm(&a, &b, &GemmConfig::paper(6, None));
        let chunked = rp_gemm(&a, &b, &GemmConfig::paper(6, Some(64)));
        let ideal = rp_gemm(&a, &b, &{
            let mut c = GemmConfig::paper(30, None);
            c.acc = FpFormat::new(11, 52);
            c
        });
        let (vn, vc, vi) = (narrow.variance(), chunked.variance(), ideal.variance());
        assert!(vc > vn, "chunked {vc} should retain more than seq {vn}");
        assert!(vc > 0.8 * vi, "chunked {vc} should approach ideal {vi}");
    }

    #[test]
    fn gemm_nzr_dense_is_one() {
        let mut rng = Pcg64::seeded(5);
        let a = Tensor::randn(&[3, 7], 1.0, &mut rng);
        let b = Tensor::randn(&[7, 2], 1.0, &mut rng);
        assert_eq!(gemm_nzr(&a, &b), 1.0);
    }

    #[test]
    fn gemm_nzr_tracks_sparsity() {
        let mut rng = Pcg64::seeded(6);
        let mut a = Tensor::randn(&[16, 64], 1.0, &mut rng);
        let b = Tensor::randn(&[64, 16], 1.0, &mut rng);
        // ReLU-like: zero out negatives in A → NZR ≈ 0.5.
        for x in a.data.iter_mut() {
            if *x < 0.0 {
                *x = 0.0;
            }
        }
        let nzr = gemm_nzr(&a, &b);
        assert!((nzr - 0.5).abs() < 0.1, "nzr={nzr}");
    }

    #[test]
    fn mxu_single_chunk_is_one_rounding() {
        // chunk ≥ K: exact dot + one rounding (+ the identity inter-chunk
        // fold, which re-rounds an already representable value).
        let mut rng = Pcg64::seeded(9);
        let a = Tensor::randn(&[2, 48], 0.5, &mut rng);
        let b = Tensor::randn(&[48, 2], 0.5, &mut rng);
        let cfg = GemmConfig::paper(8, None);
        let out = rp_gemm_mxu(&a, &b, &cfg, 48);
        let bt = b.t();
        for i in 0..2 {
            for j in 0..2 {
                let exact: f64 = (0..48)
                    .map(|l| {
                        quantize(a.at2(i, l) as f64, FpFormat::FP8_152, cfg.mode)
                            * quantize(bt.at2(j, l) as f64, FpFormat::FP8_152, cfg.mode)
                    })
                    .sum();
                let want = quantize(exact, cfg.acc, cfg.mode) as f32;
                assert_eq!(out.at2(i, j), want);
            }
        }
    }

    #[test]
    fn mxu_retains_more_than_sequential() {
        // Wide intra-chunk adders (MXU semantics) lose no variance inside
        // a chunk, so for the same m_acc they retain at least as much as
        // the per-MAC sequential path.
        let mut rng = Pcg64::seeded(10);
        let k = 4096;
        let a = Tensor::randn(&[6, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, 6], 1.0, &mut rng);
        let seq = rp_gemm(&a, &b, &GemmConfig::paper(5, None));
        let mxu = rp_gemm_mxu(&a, &b, &GemmConfig::paper(5, None), 64);
        assert!(mxu.variance() > seq.variance());
    }

    #[test]
    fn rp_dot_strided_access() {
        // B column access uses stride n — verify against a transposed copy.
        let mut rng = Pcg64::seeded(7);
        let a = Tensor::randn(&[1, 33], 0.5, &mut rng);
        let b = Tensor::randn(&[33, 5], 0.5, &mut rng);
        let bt = b.t();
        let cfg = GemmConfig::paper(12, None);
        for j in 0..5 {
            let strided = rp_dot(&a.data, 1, &b.data[j..], 5, 33, &cfg);
            let contig = rp_dot(&a.data, 1, &bt.data[j * 33..], 1, 33, &cfg);
            assert_eq!(strided, contig);
        }
    }

    #[test]
    fn rp_dot_matches_materialized_reference() {
        // The fused (allocation-free) rp_dot must equal the original
        // quantize-products-then-accumulate composition exactly.
        let mut rng = Pcg64::seeded(19);
        let a: Vec<f32> = (0..517).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..517).map(|_| rng.normal() as f32).collect();
        for cfg in [
            GemmConfig::paper(7, None),
            GemmConfig::paper(7, Some(64)),
            GemmConfig {
                mode: Rounding::TowardZero,
                ..GemmConfig::paper(7, Some(33))
            },
        ] {
            let prods: Vec<f64> = a
                .iter()
                .zip(&b)
                .map(|(&x, &y)| quantize(x as f64 * y as f64, cfg.prod, cfg.mode))
                .collect();
            let want = match cfg.chunk {
                Some(c) => chunked_sum(&prods, c, cfg.acc, cfg.mode),
                None => sequential_sum(&prods, cfg.acc, cfg.mode),
            };
            let got = rp_dot(&a, 1, &b, 1, 517, &cfg);
            assert_eq!(got.to_bits(), want.to_bits(), "cfg={cfg:?}");
        }
    }
}
