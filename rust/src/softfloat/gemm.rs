//! Reduced-precision GEMM — the software model of the paper's modified
//! CUDA GEMM: inputs quantized to the representation format (1,5,2),
//! products formed exactly in `m_p = 5` bits, and every partial sum
//! rounded to the `(1,6,m_acc)` accumulator format, optionally with
//! two-level chunked accumulation.

use super::accumulate::{chunked_sum, sequential_sum};
use super::arith::RpArith;
use super::format::FpFormat;
use super::quant::{quantize, Rounding};
use super::tensor::Tensor;

/// Configuration of a reduced-precision GEMM.
#[derive(Clone, Copy, Debug)]
pub struct GemmConfig {
    /// Representation format applied to the *inputs* (None = keep f32).
    pub repr: Option<FpFormat>,
    /// Product-term format (`m_p`).
    pub prod: FpFormat,
    /// Accumulator format (`m_acc`).
    pub acc: FpFormat,
    /// Chunk size for two-level accumulation; `None` = plain sequential.
    pub chunk: Option<usize>,
    pub mode: Rounding,
}

impl GemmConfig {
    /// Paper configuration: (1,5,2) inputs, exact 5-bit products,
    /// `(1,6,m_acc)` partial sums, optional chunk-64 accumulation.
    pub fn paper(m_acc: u32, chunk: Option<usize>) -> GemmConfig {
        GemmConfig {
            repr: Some(FpFormat::FP8_152),
            prod: FpFormat::PROD_FP8,
            acc: FpFormat::accumulator(m_acc),
            chunk,
            mode: Rounding::NearestEven,
        }
    }

    /// Full-precision baseline (no quantization anywhere) — the paper's
    /// "accumulation in full precision" control arm.
    pub fn baseline() -> GemmConfig {
        GemmConfig {
            repr: None,
            prod: FpFormat::new(11, 52),
            acc: FpFormat::new(11, 52),
            chunk: None,
            mode: Rounding::NearestEven,
        }
    }

    pub fn arith(&self) -> RpArith {
        RpArith {
            acc: self.acc,
            prod: self.prod,
            mode: self.mode,
        }
    }
}

/// One reduced-precision dot product over pre-quantized operand slices.
///
/// `a` strided by `sa`, `b` strided by `sb`, length `k`. Products are
/// rounded to `cfg.prod`, partial sums to `cfg.acc` (sequential or
/// chunked). This is the exact inner loop the VRR analysis models.
pub fn rp_dot(
    a: &[f32],
    sa: usize,
    b: &[f32],
    sb: usize,
    k: usize,
    cfg: &GemmConfig,
) -> f64 {
    // Materialize the product terms first (each rounded to m_p), then run
    // the chosen accumulation algorithm over them.
    let mut prods: Vec<f64> = Vec::with_capacity(k);
    for l in 0..k {
        let p = a[l * sa] as f64 * b[l * sb] as f64;
        prods.push(quantize(p, cfg.prod, cfg.mode));
    }
    match cfg.chunk {
        Some(c) => chunked_sum(&prods, c, cfg.acc, cfg.mode),
        None => sequential_sum(&prods, cfg.acc, cfg.mode),
    }
}

/// Reduced-precision GEMM, `C = A·B`, `A: [m,k]`, `B: [k,n]`.
///
/// Inputs are first quantized to the representation format (if any); each
/// output element is an independent length-`k` reduced-precision
/// accumulation — matching how a systolic/SIMT GEMM partitions work, and
/// matching Assumption 1's per-dot-product view.
pub fn rp_gemm(a: &Tensor, b: &Tensor, cfg: &GemmConfig) -> Tensor {
    assert_eq!(a.rank(), 2);
    assert_eq!(b.rank(), 2);
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "inner dims mismatch: {k} vs {k2}");

    // Representation quantization of the operands (the paper's (1,5,2)).
    let (aq, bq);
    let (a, b) = match cfg.repr {
        Some(fmt) => {
            aq = a.map(|x| quantize(x as f64, fmt, cfg.mode) as f32);
            bq = b.map(|x| quantize(x as f64, fmt, cfg.mode) as f32);
            (&aq, &bq)
        }
        None => (a, b),
    };

    let mut out = Tensor::zeros(&[m, n]);
    // One scratch buffer for the product terms of every dot (hot loop:
    // no per-dot allocation), and a transposed copy of B for contiguous
    // column access.
    let bt = b.t();
    let mut prods = vec![0.0f64; k];
    for i in 0..m {
        let row = &a.data[i * k..(i + 1) * k];
        for j in 0..n {
            let col = &bt.data[j * k..(j + 1) * k];
            for l in 0..k {
                prods[l] = quantize(row[l] as f64 * col[l] as f64, cfg.prod, cfg.mode);
            }
            let s = match cfg.chunk {
                Some(c) => chunked_sum(&prods, c, cfg.acc, cfg.mode),
                None => sequential_sum(&prods, cfg.acc, cfg.mode),
            };
            out.data[i * n + j] = s as f32;
        }
    }
    out
}

/// MXU-style chunked dot product — the exact semantics of the Pallas
/// kernel (python/compile/kernels/rp_gemm.py): each chunk's partial sum
/// is computed *exactly* (the hardware chunk adder tree / MXU pass),
/// rounded once to the accumulator format, and folded into a running
/// accumulator that is re-rounded after every chunk. Inputs are
/// representation-quantized to (1,5,2) first when `repr` is set.
///
/// This is the function the cross-language artifact test pins against
/// the executed HLO (rust/tests/aot_runtime.rs).
pub fn rp_dot_mxu(a: &[f32], b_col: &[f32], cfg: &GemmConfig, chunk: usize) -> f64 {
    assert_eq!(a.len(), b_col.len());
    let quant_in = |x: f32| match cfg.repr {
        Some(fmt) => quantize(x as f64, fmt, cfg.mode),
        None => x as f64,
    };
    let mut acc = 0.0f64;
    for block in a.chunks(chunk).zip(b_col.chunks(chunk)) {
        let (ab, bb) = block;
        // Exact intra-chunk sum of exact products (f64 holds both).
        let mut s = 0.0f64;
        for (&x, &y) in ab.iter().zip(bb) {
            s += quant_in(x) * quant_in(y);
        }
        let s = quantize(s, cfg.acc, cfg.mode);
        acc = quantize(acc + s, cfg.acc, cfg.mode);
    }
    acc
}

/// MXU-style reduced-precision GEMM (the Pallas kernel's semantics).
pub fn rp_gemm_mxu(a: &Tensor, b: &Tensor, cfg: &GemmConfig, chunk: usize) -> Tensor {
    let (m, k) = (a.shape[0], a.shape[1]);
    let n = b.shape[1];
    assert_eq!(k, b.shape[0]);
    let bt = b.t();
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        let row = &a.data[i * k..(i + 1) * k];
        for j in 0..n {
            let col = &bt.data[j * k..(j + 1) * k];
            out.data[i * n + j] = rp_dot_mxu(row, col, cfg, chunk) as f32;
        }
    }
    out
}

/// Measured fraction of non-zero product terms in `A·B` — the empirical
/// NZR (paper §4.3) for a GEMM's accumulations.
pub fn gemm_nzr(a: &Tensor, b: &Tensor) -> f64 {
    let (m, k) = (a.shape[0], a.shape[1]);
    let n = b.shape[1];
    let mut nonzero = 0usize;
    let mut total = 0usize;
    for i in 0..m {
        for j in 0..n {
            for l in 0..k {
                total += 1;
                if a.data[i * k + l] != 0.0 && b.data[l * n + j] != 0.0 {
                    nonzero += 1;
                }
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        nonzero as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn baseline_matches_f64_matmul() {
        let mut rng = Pcg64::seeded(1);
        let a = Tensor::randn(&[5, 32], 1.0, &mut rng);
        let b = Tensor::randn(&[32, 4], 1.0, &mut rng);
        let c = rp_gemm(&a, &b, &GemmConfig::baseline());
        let want = a.matmul(&b);
        for (x, y) in c.data.iter().zip(&want.data) {
            assert!((x - y).abs() <= 1e-5 * y.abs().max(1.0), "{x} vs {y}");
        }
    }

    #[test]
    fn wide_accumulator_close_to_baseline() {
        let mut rng = Pcg64::seeded(2);
        let a = Tensor::randn(&[4, 256], 0.2, &mut rng);
        let b = Tensor::randn(&[256, 4], 0.2, &mut rng);
        // m_acc=23 is "wide" for n=256 — only representation error remains.
        let c = rp_gemm(&a, &b, &GemmConfig::paper(23, None));
        let mut cfg8 = GemmConfig::paper(23, None);
        cfg8.acc = FpFormat::new(11, 52); // ideal accumulator, same repr
        let want = rp_gemm(&a, &b, &cfg8);
        for (x, y) in c.data.iter().zip(&want.data) {
            assert!((x - y).abs() <= 2e-2 * y.abs().max(0.5), "{x} vs {y}");
        }
    }

    #[test]
    fn narrow_accumulator_loses_variance_on_long_dots() {
        // The headline effect: long accumulation + small m_acc shrinks the
        // output ensemble variance (paper §3).
        let mut rng = Pcg64::seeded(3);
        let k = 8192;
        let a = Tensor::randn(&[8, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, 8], 1.0, &mut rng);
        let ideal = rp_gemm(&a, &b, &{
            let mut c = GemmConfig::paper(30, None);
            c.acc = FpFormat::new(11, 52);
            c
        });
        let narrow = rp_gemm(&a, &b, &GemmConfig::paper(4, None));
        let vi = ideal.variance();
        let vn = narrow.variance();
        assert!(
            vn < 0.8 * vi,
            "expected variance loss: narrow {vn} vs ideal {vi}"
        );
    }

    #[test]
    fn chunking_recovers_variance() {
        let mut rng = Pcg64::seeded(4);
        let k = 8192;
        let a = Tensor::randn(&[8, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, 8], 1.0, &mut rng);
        let narrow = rp_gemm(&a, &b, &GemmConfig::paper(6, None));
        let chunked = rp_gemm(&a, &b, &GemmConfig::paper(6, Some(64)));
        let ideal = rp_gemm(&a, &b, &{
            let mut c = GemmConfig::paper(30, None);
            c.acc = FpFormat::new(11, 52);
            c
        });
        let (vn, vc, vi) = (narrow.variance(), chunked.variance(), ideal.variance());
        assert!(vc > vn, "chunked {vc} should retain more than seq {vn}");
        assert!(vc > 0.8 * vi, "chunked {vc} should approach ideal {vi}");
    }

    #[test]
    fn gemm_nzr_dense_is_one() {
        let mut rng = Pcg64::seeded(5);
        let a = Tensor::randn(&[3, 7], 1.0, &mut rng);
        let b = Tensor::randn(&[7, 2], 1.0, &mut rng);
        assert_eq!(gemm_nzr(&a, &b), 1.0);
    }

    #[test]
    fn gemm_nzr_tracks_sparsity() {
        let mut rng = Pcg64::seeded(6);
        let mut a = Tensor::randn(&[16, 64], 1.0, &mut rng);
        let b = Tensor::randn(&[64, 16], 1.0, &mut rng);
        // ReLU-like: zero out negatives in A → NZR ≈ 0.5.
        for x in a.data.iter_mut() {
            if *x < 0.0 {
                *x = 0.0;
            }
        }
        let nzr = gemm_nzr(&a, &b);
        assert!((nzr - 0.5).abs() < 0.1, "nzr={nzr}");
    }

    #[test]
    fn mxu_single_chunk_is_one_rounding() {
        // chunk ≥ K: exact dot + one rounding (+ the identity inter-chunk
        // fold, which re-rounds an already representable value).
        let mut rng = Pcg64::seeded(9);
        let a = Tensor::randn(&[2, 48], 0.5, &mut rng);
        let b = Tensor::randn(&[48, 2], 0.5, &mut rng);
        let cfg = GemmConfig::paper(8, None);
        let out = rp_gemm_mxu(&a, &b, &cfg, 48);
        let bt = b.t();
        for i in 0..2 {
            for j in 0..2 {
                let exact: f64 = (0..48)
                    .map(|l| {
                        quantize(a.at2(i, l) as f64, FpFormat::FP8_152, cfg.mode)
                            * quantize(bt.at2(j, l) as f64, FpFormat::FP8_152, cfg.mode)
                    })
                    .sum();
                let want = quantize(exact, cfg.acc, cfg.mode) as f32;
                assert_eq!(out.at2(i, j), want);
            }
        }
    }

    #[test]
    fn mxu_retains_more_than_sequential() {
        // Wide intra-chunk adders (MXU semantics) lose no variance inside
        // a chunk, so for the same m_acc they retain at least as much as
        // the per-MAC sequential path.
        let mut rng = Pcg64::seeded(10);
        let k = 4096;
        let a = Tensor::randn(&[6, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, 6], 1.0, &mut rng);
        let seq = rp_gemm(&a, &b, &GemmConfig::paper(5, None));
        let mxu = rp_gemm_mxu(&a, &b, &GemmConfig::paper(5, None), 64);
        assert!(mxu.variance() > seq.variance());
    }

    #[test]
    fn rp_dot_strided_access() {
        // B column access uses stride n — verify against a transposed copy.
        let mut rng = Pcg64::seeded(7);
        let a = Tensor::randn(&[1, 33], 0.5, &mut rng);
        let b = Tensor::randn(&[33, 5], 0.5, &mut rng);
        let bt = b.t();
        let cfg = GemmConfig::paper(12, None);
        for j in 0..5 {
            let strided = rp_dot(&a.data, 1, &b.data[j..], 5, 33, &cfg);
            let contig = rp_dot(&a.data, 1, &bt.data[j * 33..], 1, 33, &cfg);
            assert_eq!(strided, contig);
        }
    }
}
