//! Bit-accurate simulation of custom reduced-precision floating-point.
//!
//! The paper's experiments modify the GEMM inner loop so every partial sum
//! is rounded to an `(1, e, m_acc)` floating-point value — the hardware
//! behaviour of a reduced-width accumulator. This module is the software
//! stand-in for that hardware: a *fake-quantization* simulator that keeps
//! values in `f64` but rounds the mantissa to `m` bits (and clamps the
//! exponent to `e` bits) after every arithmetic operation.
//!
//! Exactness argument (see DESIGN.md §7): every `(1,e,m)` value with
//! `m ≤ 23` is exactly representable in `f64`; products of two `m_p`-bit
//! mantissas need `2·m_p+1 ≤ 53` bits; sums round at most once below the
//! target quantum. The simulator therefore reproduces the swamping
//! behaviour of real narrow accumulators bit-for-bit for every format the
//! paper studies.

pub mod accumulate;
pub mod arith;
pub mod format;
pub mod gemm;
pub mod quant;
pub mod tensor;
pub mod value;

pub use accumulate::{chunked_sum, pairwise_sum, sequential_sum, Accumulator};
pub use format::FpFormat;
pub use gemm::{
    rp_gemm, rp_gemm_ex, rp_gemm_packed, rp_gemm_ref, GemmConfig, GemmCtx, Interrupted, Layout,
    QuantizedOperand,
};
pub use quant::{quantize, Quantizer, Rounding};
pub use tensor::Tensor;
