//! Fake-quantization: round an `f64` to the nearest value representable in
//! a target `(1,e,m)` format.
//!
//! This is the primitive the whole simulator is built on — applied after
//! every multiply and every partial-sum addition it reproduces the
//! behaviour of narrow hardware datapaths, including the *swamping*
//! phenomenon the paper analyzes (large `|s_i|` causing the low-order bits
//! of an incoming product term to be shifted out and truncated).

use super::format::FpFormat;

/// Rounding mode applied to the mantissa.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum Rounding {
    /// Round to nearest, ties to even — IEEE default, and what the paper's
    /// modified CUDA GEMM implements.
    #[default]
    NearestEven,
    /// Truncation toward zero — the classical "chopping" accumulator,
    /// matching the bit-discard picture of paper Figure 4.
    TowardZero,
}

/// Quantize `x` to the format `fmt` under rounding mode `mode`.
///
/// Semantics:
/// * exact zero, NaN and ±∞ pass through;
/// * overflow beyond `max_finite` saturates to ±∞ (IEEE RNE behaviour for
///   values ≥ the overflow threshold; the trainer treats ∞ as divergence);
/// * gradual underflow: below `2^{e_min}` the quantum freezes at
///   `2^{e_min-m}` (subnormals), below half the smallest subnormal the
///   value flushes to ±0.
pub fn quantize(x: f64, fmt: FpFormat, mode: Rounding) -> f64 {
    Quantizer::new(fmt, mode).quantize(x)
}

/// Rounding behaviour lifted to the type level, so hot loops (the GEMM
/// kernel, [`Quantizer::quantize_m`]) can be monomorphized per mode
/// instead of matching on [`Rounding`] once per element. Both impls are
/// zero-sized.
pub trait RoundMode: 'static {
    /// The dynamic mode this type stands for.
    const MODE: Rounding;
    /// Round a value already scaled to an integer count of quanta.
    fn round(scaled: f64) -> f64;
    /// Resolve an overflow past `max_finite` (sign taken from `y`).
    fn overflow(y: f64, max: f64) -> f64;
}

/// Type-level [`Rounding::NearestEven`].
#[derive(Clone, Copy, Debug, Default)]
pub struct Rne;

/// Type-level [`Rounding::TowardZero`].
#[derive(Clone, Copy, Debug, Default)]
pub struct Rtz;

impl RoundMode for Rne {
    const MODE: Rounding = Rounding::NearestEven;

    #[inline(always)]
    fn round(scaled: f64) -> f64 {
        scaled.round_ties_even()
    }

    #[inline(always)]
    fn overflow(y: f64, _max: f64) -> f64 {
        // IEEE: round-to-nearest overflows to ∞ once past the midpoint
        // between max_finite and the next (unrepresentable) value; the
        // scaled rounding already decided that.
        if y > 0.0 {
            f64::INFINITY
        } else {
            f64::NEG_INFINITY
        }
    }
}

impl RoundMode for Rtz {
    const MODE: Rounding = Rounding::TowardZero;

    #[inline(always)]
    fn round(scaled: f64) -> f64 {
        scaled.trunc()
    }

    #[inline(always)]
    fn overflow(y: f64, max: f64) -> f64 {
        if y > 0.0 {
            max
        } else {
            -max
        }
    }
}

/// A quantizer with the per-format constants hoisted out of the call:
/// mantissa width, `e_min`, `max_finite`, and the `man_bits >= 52`
/// identity test are computed once at construction instead of once per
/// quantized value. [`Quantizer::quantize`] is bit-for-bit identical to
/// the free [`quantize`] function (which now delegates here; the
/// equivalence is additionally pinned by a PCG property sweep in
/// `tests/gemm.rs` spanning subnormal, normal, and overflow ranges).
#[derive(Clone, Copy, Debug)]
pub struct Quantizer {
    mode: Rounding,
    /// Target at least as wide as f64 — quantization is the identity.
    identity: bool,
    m: i32,
    e_min: i32,
    max: f64,
}

impl Quantizer {
    pub fn new(fmt: FpFormat, mode: Rounding) -> Quantizer {
        Quantizer {
            mode,
            identity: fmt.man_bits >= 52,
            m: fmt.man_bits as i32,
            e_min: fmt.e_min(),
            max: fmt.max_finite(),
        }
    }

    /// True iff the target format is at least as wide as f64 itself, so
    /// quantization passes every finite value through unchanged. Kernels
    /// branch on this once per panel instead of once per element.
    #[inline]
    pub fn is_identity(&self) -> bool {
        self.identity
    }

    /// Quantize `x` — dispatches once on the stored mode.
    #[inline]
    pub fn quantize(&self, x: f64) -> f64 {
        match self.mode {
            Rounding::NearestEven => self.quantize_m::<Rne>(x),
            Rounding::TowardZero => self.quantize_m::<Rtz>(x),
        }
    }

    /// Monomorphized quantize; `R` must match the constructed mode (the
    /// GEMM kernel resolves `R` once per config and calls this in its
    /// fused quantize-MAC inner loop).
    #[inline]
    pub fn quantize_m<R: RoundMode>(&self, x: f64) -> f64 {
        debug_assert_eq!(R::MODE, self.mode);
        if self.identity || x == 0.0 || !x.is_finite() {
            return x;
        }
        // Unbiased exponent of |x| via bit inspection (exact, unlike log2).
        let bits = x.abs().to_bits();
        let raw_exp = ((bits >> 52) & 0x7ff) as i32;
        let e = if raw_exp == 0 {
            // f64-subnormal input (astronomically below any simulated format).
            -1074 + (63 - (bits.leading_zeros() as i32)) // exponent of leading bit
        } else {
            raw_exp - 1023
        };

        // Quantum: 2^(e-m) for normals, frozen at 2^(e_min-m) in the
        // subnormal range of the target format.
        let q_exp = if e < self.e_min {
            self.e_min - self.m
        } else {
            e - self.m
        };
        // 2^±q_exp as exact bit patterns — every format we simulate keeps
        // q_exp well inside f64's normal exponent range (hot path: avoids
        // powi and the division).
        debug_assert!((-1022..=1022).contains(&q_exp));
        let quantum = f64::from_bits(((q_exp + 1023) as u64) << 52);
        let inv_quantum = f64::from_bits(((-q_exp + 1023) as u64) << 52);
        let y = R::round(x * inv_quantum) * quantum;

        // Overflow handling (the rounding may also have bumped into the
        // next binade, possibly crossing e_max).
        if y.abs() > self.max {
            R::overflow(y, self.max)
        } else {
            y
        }
    }
}

/// Quantize with round-to-nearest-even (the common case).
#[inline]
pub fn quantize_rne(x: f64, fmt: FpFormat) -> f64 {
    quantize(x, fmt, Rounding::NearestEven)
}

/// Quantize every element of a slice in place.
pub fn quantize_slice(xs: &mut [f64], fmt: FpFormat, mode: Rounding) {
    for x in xs.iter_mut() {
        *x = quantize(*x, fmt, mode);
    }
}

/// Quantize an `f32` tensor's values (used to produce (1,5,2) operands).
pub fn quantize_f32(xs: &mut [f32], fmt: FpFormat, mode: Rounding) {
    for x in xs.iter_mut() {
        *x = quantize(*x as f64, fmt, mode) as f32;
    }
}

/// True iff `x` is exactly representable in `fmt`.
pub fn is_representable(x: f64, fmt: FpFormat) -> bool {
    quantize(x, fmt, Rounding::NearestEven) == x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    const FP8: FpFormat = FpFormat::FP8_152;

    #[test]
    fn exact_values_pass_through() {
        for fmt in [FpFormat::FP32, FpFormat::FP16, FP8, FpFormat::accumulator(9)] {
            for v in [0.0, 1.0, -1.5, 0.25, 2.0_f64.powi(fmt.e_min())] {
                assert_eq!(quantize(v, fmt, Rounding::NearestEven), v, "{fmt} {v}");
            }
        }
    }

    #[test]
    fn rne_ties_to_even() {
        // (1,5,2): representable mantissas at 1.00, 1.25, 1.50, 1.75.
        // 1.125 is exactly halfway between 1.0 and 1.25 → ties to even (1.0,
        // mantissa bits 00). 1.375 halfway between 1.25 and 1.5 → 1.5
        // (mantissa 10 is even vs 01 odd).
        assert_eq!(quantize(1.125, FP8, Rounding::NearestEven), 1.0);
        assert_eq!(quantize(1.375, FP8, Rounding::NearestEven), 1.5);
        assert_eq!(quantize(-1.125, FP8, Rounding::NearestEven), -1.0);
    }

    #[test]
    fn truncation_chops_toward_zero() {
        assert_eq!(quantize(1.24, FP8, Rounding::TowardZero), 1.0);
        assert_eq!(quantize(-1.24, FP8, Rounding::TowardZero), -1.0);
        assert_eq!(quantize(1.999, FP8, Rounding::TowardZero), 1.75);
    }

    #[test]
    fn rounding_crosses_binade() {
        // 1.97 rounds up to 2.0 (next binade) in (1,5,2).
        assert_eq!(quantize(1.97, FP8, Rounding::NearestEven), 2.0);
    }

    #[test]
    fn overflow_saturates_to_infinity() {
        let max = FP8.max_finite(); // 57344
        assert_eq!(quantize(max, FP8, Rounding::NearestEven), max);
        assert_eq!(
            quantize(max * 1.26, FP8, Rounding::NearestEven),
            f64::INFINITY
        );
        assert_eq!(quantize(max * 1.26, FP8, Rounding::TowardZero), max);
        assert_eq!(
            quantize(-max * 2.0, FP8, Rounding::NearestEven),
            f64::NEG_INFINITY
        );
    }

    #[test]
    fn subnormal_range_and_flush() {
        let fmt = FpFormat::FP16;
        let min_sub = fmt.min_subnormal(); // 2^-24
        assert_eq!(quantize(min_sub, fmt, Rounding::NearestEven), min_sub);
        // 0.4 × min_sub rounds to zero; 0.6 × min_sub rounds to min_sub.
        assert_eq!(quantize(0.4 * min_sub, fmt, Rounding::NearestEven), 0.0);
        assert_eq!(
            quantize(0.6 * min_sub, fmt, Rounding::NearestEven),
            min_sub
        );
        // Subnormal spacing is uniform at min_sub: integer multiples are
        // representable, halfway points tie to even.
        assert_eq!(
            quantize(3.0 * min_sub, fmt, Rounding::NearestEven),
            3.0 * min_sub
        );
        assert_eq!(
            quantize(3.5 * min_sub, fmt, Rounding::NearestEven),
            4.0 * min_sub // tie between 3 and 4 → even (4)
        );
    }

    #[test]
    fn matches_f32_hardware_rounding() {
        // Quantizing to (1,8,23) must agree exactly with the hardware f32
        // cast for a large random sample — the strongest available oracle.
        let mut rng = Pcg64::seeded(99);
        for _ in 0..200_000 {
            let x = rng.normal() * 2f64.powi((rng.next_below(80) as i32) - 40);
            let ours = quantize(x, FpFormat::FP32, Rounding::NearestEven);
            let hw = x as f32 as f64;
            assert_eq!(ours, hw, "x={x:e}");
        }
    }

    #[test]
    fn idempotent() {
        let mut rng = Pcg64::seeded(4);
        for fmt in [FP8, FpFormat::accumulator(7), FpFormat::FP16] {
            for _ in 0..10_000 {
                let x = rng.normal() * 100.0;
                let q = quantize(x, fmt, Rounding::NearestEven);
                assert_eq!(q, quantize(q, fmt, Rounding::NearestEven));
            }
        }
    }

    #[test]
    fn monotone_nondecreasing() {
        // Quantization must preserve order (weak monotonicity).
        let mut rng = Pcg64::seeded(17);
        let fmt = FpFormat::accumulator(5);
        let mut xs: Vec<f64> = (0..5000).map(|_| rng.normal() * 10.0).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let qs: Vec<f64> = xs
            .iter()
            .map(|&x| quantize(x, fmt, Rounding::NearestEven))
            .collect();
        for w in qs.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn error_bounded_by_half_ulp() {
        let mut rng = Pcg64::seeded(23);
        let fmt = FpFormat::accumulator(9);
        for _ in 0..50_000 {
            let x = rng.normal() * 8.0;
            if x == 0.0 {
                continue;
            }
            let q = quantize(x, fmt, Rounding::NearestEven);
            let ulp = 2f64.powi(
                (x.abs().log2().floor() as i32).max(fmt.e_min()) - fmt.man_bits as i32,
            );
            assert!(
                (q - x).abs() <= 0.5 * ulp + 1e-300,
                "x={x} q={q} ulp={ulp}"
            );
        }
    }

    #[test]
    fn scale_invariance_by_powers_of_two() {
        // Floating-point rounding commutes with exact binary scaling as
        // long as no range boundary is crossed: q(2^k·x) = 2^k·q(x).
        // This is the property that makes the VRR analysis independent of
        // σ_p — worth pinning on the simulator. Values are kept well
        // inside the (1,6,m) normal range so no boundary is crossed.
        let mut rng = Pcg64::seeded(41);
        for fmt in [FpFormat::accumulator(2), FpFormat::accumulator(7), FpFormat::accumulator(12)] {
            for _ in 0..20_000 {
                let x = rng.normal();
                if x.abs() < 1e-3 {
                    continue;
                }
                let k = rng.next_below(13) as i32 - 6;
                let s = 2f64.powi(k);
                let a = quantize(x * s, fmt, Rounding::NearestEven);
                let b = quantize(x, fmt, Rounding::NearestEven) * s;
                assert_eq!(a, b, "fmt={fmt} x={x} k={k}");
            }
        }
    }

    #[test]
    fn quantizer_matches_free_function() {
        // The precomputed-constant path must agree with the reference
        // free function on every input class, both modes, including the
        // identity (wide) formats and non-finite pass-through.
        let mut rng = Pcg64::seeded(61);
        for fmt in [FP8, FpFormat::accumulator(7), FpFormat::FP16, FpFormat::new(11, 52)] {
            for mode in [Rounding::NearestEven, Rounding::TowardZero] {
                let q = Quantizer::new(fmt, mode);
                for special in [0.0, -0.0, f64::INFINITY, f64::NEG_INFINITY] {
                    assert_eq!(
                        q.quantize(special).to_bits(),
                        quantize(special, fmt, mode).to_bits()
                    );
                }
                assert!(q.quantize(f64::NAN).is_nan());
                for _ in 0..20_000 {
                    let x = rng.normal() * 2f64.powi((rng.next_below(40) as i32) - 20);
                    assert_eq!(
                        q.quantize(x).to_bits(),
                        quantize(x, fmt, mode).to_bits(),
                        "fmt={fmt} mode={mode:?} x={x:e}"
                    );
                }
            }
        }
    }

    #[test]
    fn quantizer_identity_flag() {
        assert!(Quantizer::new(FpFormat::new(11, 52), Rounding::NearestEven).is_identity());
        assert!(!Quantizer::new(FP8, Rounding::NearestEven).is_identity());
    }

    #[test]
    fn nan_and_inf_pass_through() {
        assert!(quantize(f64::NAN, FP8, Rounding::NearestEven).is_nan());
        assert_eq!(
            quantize(f64::INFINITY, FP8, Rounding::NearestEven),
            f64::INFINITY
        );
    }
}
