//! A minimal dense row-major tensor over `f32` — just enough linear
//! algebra for the native trainer and the GEMM simulator (no ndarray in
//! the offline environment).

use crate::util::Pcg64;

/// Row-major dense tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape/data mismatch"
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    /// He-style normal init: std = gain / sqrt(fan_in).
    pub fn randn(shape: &[usize], std: f64, rng: &mut Pcg64) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// 2-D accessors (used pervasively by the GEMM paths).
    #[inline]
    pub fn at2(&self, r: usize, c: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[r * self.shape[1] + c]
    }

    #[inline]
    pub fn set2(&mut self, r: usize, c: usize, v: f32) {
        debug_assert_eq!(self.rank(), 2);
        self.data[r * self.shape[1] + c] = v;
    }

    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Transpose a 2-D tensor.
    pub fn t(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[n, m]);
        for i in 0..m {
            for j in 0..n {
                out.data[j * m + i] = self.data[i * n + j];
            }
        }
        out
    }

    /// Exact f32 matmul (reference / baseline path), self: [m,k] × [k,n].
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "inner dims mismatch");
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for l in 0..k {
                let a = self.data[i * k + l] as f64;
                if a == 0.0 {
                    continue;
                }
                for j in 0..n {
                    // f64 accumulate = the paper's "full precision" baseline.
                    let cur = out.data[i * n + j] as f64;
                    out.data[i * n + j] = (cur + a * other.data[l * n + j] as f64) as f32;
                }
            }
        }
        out
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    /// Fraction of non-zero entries — the NZR the sparsity correction
    /// (paper §4.3) feeds on.
    pub fn nzr(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().filter(|&&x| x != 0.0).count() as f64 / self.data.len() as f64
    }

    /// Population variance of the entries (for Fig. 3-style snapshots).
    pub fn variance(&self) -> f64 {
        if self.data.is_empty() {
            return f64::NAN;
        }
        let n = self.data.len() as f64;
        let mean = self.data.iter().map(|&x| x as f64).sum::<f64>() / n;
        self.data
            .iter()
            .map(|&x| (x as f64 - mean) * (x as f64 - mean))
            .sum::<f64>()
            / n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seeded(2);
        let a = Tensor::randn(&[3, 5], 1.0, &mut rng);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::seeded(3);
        let a = Tensor::randn(&[4, 4], 1.0, &mut rng);
        let mut eye = Tensor::zeros(&[4, 4]);
        for i in 0..4 {
            eye.set2(i, i, 1.0);
        }
        let prod = a.matmul(&eye);
        for (x, y) in prod.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn nzr_counts_zeros() {
        let t = Tensor::from_vec(&[4], vec![0.0, 1.0, 0.0, 2.0]);
        assert_eq!(t.nzr(), 0.5);
    }

    #[test]
    fn variance_of_constant_is_zero() {
        let t = Tensor::from_vec(&[3], vec![2.0, 2.0, 2.0]);
        assert!(t.variance().abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn matmul_dim_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 2]);
        let _ = a.matmul(&b);
    }
}
