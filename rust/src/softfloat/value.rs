//! Bit-level encoding of custom `(1, e, m)` floating-point values —
//! the storage side of the numeric-format library: pack a (quantized)
//! value into its `1+e+m`-bit pattern and back, exactly as a hardware
//! register or a serialized low-precision tensor would hold it.
//!
//! Round-trip guarantee: `decode(encode(x)) == x` for every value
//! representable in the format (including subnormals, ±0, ±∞); for
//! non-representable inputs `encode` first rounds with RNE — i.e.
//! `decode(encode(x)) == quantize(x)`.

use super::format::FpFormat;
use super::quant::{quantize, Rounding};

/// Encode `x` into the format's bit pattern (low `1+e+m` bits of the
/// returned word; sign in the top of those).
pub fn encode(x: f64, fmt: FpFormat) -> u64 {
    let e_bits = fmt.exp_bits;
    let m_bits = fmt.man_bits;
    let sign = if x.is_sign_negative() { 1u64 } else { 0 };
    let sign_field = sign << (e_bits + m_bits);

    let q = quantize(x, fmt, Rounding::NearestEven);
    if q == 0.0 {
        return sign_field;
    }
    if q.is_nan() {
        // Canonical quiet NaN: all-ones exponent, top mantissa bit set.
        let exp_all = ((1u64 << e_bits) - 1) << m_bits;
        return sign_field | exp_all | (1u64 << (m_bits.max(1) - 1));
    }
    if q.is_infinite() {
        let exp_all = ((1u64 << e_bits) - 1) << m_bits;
        return sign_field | exp_all;
    }

    let a = q.abs();
    let bits = a.to_bits();
    let e_unbiased = ((bits >> 52) & 0x7ff) as i32 - 1023;
    let bias = fmt.bias();
    if e_unbiased >= fmt.e_min() {
        // Normal: biased exponent in [1, 2^e - 2], top m mantissa bits.
        let exp_field = (e_unbiased + bias) as u64;
        let mant_field = (bits >> (52 - m_bits)) & ((1u64 << m_bits) - 1);
        sign_field | (exp_field << m_bits) | mant_field
    } else {
        // Subnormal: value = mant · 2^(e_min - m), exponent field 0.
        let mant = (a / fmt.min_subnormal()).round() as u64;
        debug_assert!(mant < (1u64 << m_bits));
        sign_field | mant
    }
}

/// Decode a bit pattern (as produced by [`encode`]) back to `f64`.
pub fn decode(word: u64, fmt: FpFormat) -> f64 {
    let e_bits = fmt.exp_bits;
    let m_bits = fmt.man_bits;
    let sign = if (word >> (e_bits + m_bits)) & 1 == 1 {
        -1.0
    } else {
        1.0
    };
    let exp_field = (word >> m_bits) & ((1u64 << e_bits) - 1);
    let mant_field = word & ((1u64 << m_bits) - 1);

    if exp_field == (1 << e_bits) - 1 {
        return if mant_field == 0 {
            sign * f64::INFINITY
        } else {
            f64::NAN
        };
    }
    if exp_field == 0 {
        // Subnormal (or zero).
        return sign * mant_field as f64 * fmt.min_subnormal();
    }
    let e_unbiased = exp_field as i32 - fmt.bias();
    let mantissa = 1.0 + mant_field as f64 / (1u64 << m_bits) as f64;
    sign * mantissa * 2f64.powi(e_unbiased)
}

/// Pack a slice of values into contiguous words (one per value — dense
/// sub-byte packing is left to the storage layer).
pub fn encode_slice(xs: &[f32], fmt: FpFormat) -> Vec<u64> {
    xs.iter().map(|&x| encode(x as f64, fmt)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    const FORMATS: [FpFormat; 4] = [
        FpFormat::FP8_152,
        FpFormat::FP16,
        FpFormat::accumulator(9),
        FpFormat::accumulator(12),
    ];

    #[test]
    fn roundtrip_equals_quantize() {
        let mut rng = Pcg64::seeded(19);
        for fmt in FORMATS {
            for _ in 0..20_000 {
                let x = rng.normal() * 2f64.powi(rng.next_below(20) as i32 - 10);
                let q = quantize(x, fmt, Rounding::NearestEven);
                if !q.is_finite() {
                    continue; // overflow → inf; checked separately
                }
                let back = decode(encode(x, fmt), fmt);
                assert_eq!(back, q, "{fmt} x={x}");
            }
        }
    }

    #[test]
    fn fits_in_declared_width() {
        let mut rng = Pcg64::seeded(23);
        for fmt in FORMATS {
            for _ in 0..5_000 {
                let x = rng.normal() * 10.0;
                let w = encode(x, fmt);
                assert!(w < (1u64 << fmt.bits()), "{fmt} word {w:#x}");
            }
        }
    }

    #[test]
    fn special_values() {
        let fmt = FpFormat::FP8_152;
        assert_eq!(decode(encode(0.0, fmt), fmt), 0.0);
        assert_eq!(decode(encode(f64::INFINITY, fmt), fmt), f64::INFINITY);
        assert_eq!(
            decode(encode(f64::NEG_INFINITY, fmt), fmt),
            f64::NEG_INFINITY
        );
        assert!(decode(encode(f64::NAN, fmt), fmt).is_nan());
        // Negative zero keeps its sign bit.
        let neg_zero = encode(-0.0, fmt);
        assert_eq!(neg_zero >> (fmt.exp_bits + fmt.man_bits), 1);
    }

    #[test]
    fn subnormal_roundtrip() {
        let fmt = FpFormat::FP16;
        for k in 1..16u64 {
            let x = k as f64 * fmt.min_subnormal();
            assert_eq!(decode(encode(x, fmt), fmt), x, "k={k}");
        }
    }

    #[test]
    fn exhaustive_fp8_roundtrip() {
        // All 256 bit patterns of (1,5,2): decode → encode is the
        // identity (except NaN payloads, canonicalized).
        let fmt = FpFormat::FP8_152;
        for w in 0u64..256 {
            let v = decode(w, fmt);
            if v.is_nan() {
                continue;
            }
            let back = encode(v, fmt);
            assert_eq!(back, w, "w={w:#04x} v={v}");
        }
    }

    #[test]
    fn encode_slice_shape() {
        let words = encode_slice(&[1.0, -1.5, 0.25], FpFormat::FP8_152);
        assert_eq!(words.len(), 3);
        assert_eq!(decode(words[1], FpFormat::FP8_152), -1.5);
    }
}
