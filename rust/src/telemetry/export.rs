//! Prometheus text exposition (version 0.0.4) for [`TelemetrySnapshot`].
//!
//! Registry keys already use the series syntax `base{k="v",...}` (see
//! [`super::registry::labeled`]); the exporter splits the base name off,
//! emits one `# TYPE` line per base, and for histograms expands the
//! log2 buckets into cumulative `_bucket{le="..."}` series plus `_sum`
//! and `_count`.
//!
//! Label suffixes are not trusted: keys inserted directly by collectors
//! (bypassing [`super::registry::labeled`]) may carry raw `"`, `\` or
//! newlines that would corrupt the line-oriented exposition format. The
//! exporter re-parses every suffix and re-serializes it with the
//! exposition escapes (`\\`, `\"`, `\n`); suffixes that are not label
//! syntax at all are dropped so the base series still exports.

use std::collections::BTreeSet;
use std::fmt::Write as _;

use super::metric::bucket_upper;
use super::snapshot::TelemetrySnapshot;

/// Base metric name (before any `{labels}`) sanitized to the exposition
/// charset `[a-zA-Z0-9_:]`.
fn sanitize_base(name: &str) -> String {
    name.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
                c
            } else {
                '_'
            }
        })
        .collect()
}

/// Label name sanitized to the exposition charset `[a-zA-Z0-9_]` with a
/// non-digit first character.
fn sanitize_label_name(name: &str) -> String {
    let mut out: String = name
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '_' {
                c
            } else {
                '_'
            }
        })
        .collect();
    if out.is_empty() || out.as_bytes()[0].is_ascii_digit() {
        out.insert(0, '_');
    }
    out
}

/// Escape a label value per the exposition rules: backslash, double
/// quote and newline must be `\\`, `\"` and `\n`.
fn escape_label_value(value: &str) -> String {
    let mut out = String::with_capacity(value.len());
    for ch in value.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Leniently parse a `{k="v",...}` suffix into label pairs, resolving
/// `\\` / `\"` / `\n` escapes (so keys built by [`labeled`] round-trip)
/// while also tolerating raw newlines inside values. Returns `None` when
/// the suffix is not label syntax.
///
/// [`labeled`]: super::registry::labeled
fn parse_labels(suffix: &str) -> Option<Vec<(String, String)>> {
    let inner = suffix.strip_prefix('{')?.strip_suffix('}')?;
    let mut pairs = Vec::new();
    let mut rest = inner;
    while !rest.is_empty() {
        let eq = rest.find("=\"")?;
        let name = &rest[..eq];
        let mut value = String::new();
        let mut end = None;
        let mut chars = rest[eq + 2..].char_indices();
        while let Some((i, c)) = chars.next() {
            match c {
                '\\' => match chars.next() {
                    Some((_, 'n')) => value.push('\n'),
                    Some((_, esc @ ('\\' | '"'))) => value.push(esc),
                    // Not an exposition escape: keep the raw backslash.
                    Some((_, other)) => {
                        value.push('\\');
                        value.push(other);
                    }
                    None => return None,
                },
                '"' => {
                    end = Some(i);
                    break;
                }
                c => value.push(c),
            }
        }
        pairs.push((name.to_string(), value));
        rest = &rest[eq + 2 + end? + 1..];
        match rest.strip_prefix(',') {
            Some(r) => rest = r,
            None if rest.is_empty() => {}
            None => return None,
        }
    }
    Some(pairs)
}

/// Re-serialize parsed label pairs with sanitized names and escaped
/// values — always valid exposition output.
fn render_labels(pairs: &[(String, String)]) -> String {
    let mut s = String::from("{");
    for (i, (k, v)) in pairs.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&sanitize_label_name(k));
        s.push_str("=\"");
        s.push_str(&escape_label_value(v));
        s.push('"');
    }
    s.push('}');
    s
}

/// Split a registry key into (sanitized base, re-escaped label suffix).
/// An unparseable suffix is dropped rather than emitted verbatim, so one
/// hand-built key can never corrupt the whole exposition page.
fn split_series(key: &str) -> (String, String) {
    match key.find('{') {
        Some(i) => {
            let base = sanitize_base(&key[..i]);
            match parse_labels(&key[i..]) {
                Some(pairs) if !pairs.is_empty() => (base, render_labels(&pairs)),
                _ => (base, String::new()),
            }
        }
        None => (sanitize_base(key), String::new()),
    }
}

/// Append `le="<upper>"` to an existing label suffix (`""` or `{...}`).
fn with_le(labels: &str, le: &str) -> String {
    if labels.is_empty() {
        format!("{{le=\"{le}\"}}")
    } else {
        // `{k="v"}` -> `{k="v",le="..."}`
        format!("{},le=\"{le}\"}}", &labels[..labels.len() - 1])
    }
}

impl TelemetrySnapshot {
    /// Render the snapshot as Prometheus text exposition. Deterministic:
    /// series are emitted in `BTreeMap` key order, so labeled series of
    /// one base name stay adjacent under a single `# TYPE` line.
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        let mut typed: BTreeSet<String> = BTreeSet::new();

        for (key, &v) in &self.counters {
            let (base, labels) = split_series(key);
            if typed.insert(base.clone()) {
                let _ = writeln!(out, "# TYPE {base} counter");
            }
            let _ = writeln!(out, "{base}{labels} {v}");
        }
        for (key, &v) in &self.gauges {
            let (base, labels) = split_series(key);
            if typed.insert(base.clone()) {
                let _ = writeln!(out, "# TYPE {base} gauge");
            }
            let _ = writeln!(out, "{base}{labels} {v}");
        }
        for (key, h) in &self.histograms {
            let (base, labels) = split_series(key);
            if typed.insert(base.clone()) {
                let _ = writeln!(out, "# TYPE {base} histogram");
            }
            let highest = h
                .buckets
                .iter()
                .rposition(|&c| c > 0)
                .map(|i| i + 1)
                .unwrap_or(0);
            let mut cum = 0u64;
            for (i, &c) in h.buckets.iter().enumerate().take(highest) {
                cum += c;
                let le = bucket_upper(i).to_string();
                let _ = writeln!(out, "{base}_bucket{} {cum}", with_le(&labels, &le));
            }
            let _ = writeln!(out, "{base}_bucket{} {}", with_le(&labels, "+Inf"), h.count);
            let _ = writeln!(out, "{base}_sum{labels} {}", h.sum);
            let _ = writeln!(out, "{base}_count{labels} {}", h.count);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::metric::Histogram;
    use crate::telemetry::registry::labeled;

    fn sample() -> TelemetrySnapshot {
        let mut s = TelemetrySnapshot::default();
        s.counters.insert("reqs_total".into(), 10);
        s.counters
            .insert(labeled("reqs_total", &[("kind", "train")]), 4);
        s.gauges.insert("queue_depth".into(), 3);
        let h = Histogram::new();
        for v in [3u64, 5, 100, 2_000] {
            h.record(v);
        }
        s.histograms.insert("lat_ns".into(), h.snapshot());
        s.histograms.insert(
            labeled("lat_ns", &[("net", "resnet32")]),
            Histogram::new().snapshot(),
        );
        s
    }

    #[test]
    fn type_line_emitted_once_per_base() {
        let text = sample().prometheus();
        assert_eq!(text.matches("# TYPE reqs_total counter").count(), 1);
        assert_eq!(text.matches("# TYPE lat_ns histogram").count(), 1);
        assert!(text.contains("# TYPE queue_depth gauge"));
        assert!(text.contains("reqs_total 10"));
        assert!(text.contains("reqs_total{kind=\"train\"} 4"));
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_capped_by_inf() {
        let text = sample().prometheus();
        // 3 and 5 share no octave boundary with 100 and 2000: buckets at
        // le=4 (count 1), le=8 (2), le=128 (3), le=4096 (4), +Inf (4).
        assert!(text.contains("lat_ns_bucket{le=\"4\"} 1"));
        assert!(text.contains("lat_ns_bucket{le=\"8\"} 2"));
        assert!(text.contains("lat_ns_bucket{le=\"128\"} 3"));
        assert!(text.contains("lat_ns_bucket{le=\"4096\"} 4"));
        assert!(text.contains("lat_ns_bucket{le=\"+Inf\"} 4"));
        assert!(text.contains("lat_ns_sum 2108"));
        assert!(text.contains("lat_ns_count 4"));
        // Empty labeled series still expose +Inf/sum/count.
        assert!(text.contains("lat_ns_bucket{net=\"resnet32\",le=\"+Inf\"} 0"));
        assert!(text.contains("lat_ns_count{net=\"resnet32\"} 0"));
    }

    #[test]
    fn exposition_lines_are_well_formed() {
        // Mini-validator: every non-comment line is `name[{labels}] value`
        // with a parseable numeric value and a sane name charset.
        let text = sample().prometheus();
        assert!(!text.is_empty());
        for line in text.lines() {
            if line.starts_with('#') {
                assert!(line.starts_with("# TYPE "), "bad comment: {line}");
                continue;
            }
            let (series, value) = line.rsplit_once(' ').expect("name value");
            assert!(
                value.parse::<f64>().is_ok() || value == "+Inf",
                "bad value in: {line}"
            );
            let name_end = series.find('{').unwrap_or(series.len());
            assert!(
                series[..name_end]
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
                "bad name in: {line}"
            );
            if name_end < series.len() {
                assert!(series.ends_with('}'), "unterminated labels: {line}");
            }
        }
    }

    #[test]
    fn base_names_are_sanitized() {
        let mut s = TelemetrySnapshot::default();
        s.counters.insert("bad.name-1".into(), 1);
        assert!(s.prometheus().contains("bad_name_1 1"));
    }

    #[test]
    fn labeled_keys_round_trip_without_double_escaping() {
        // `labeled` already escaped these; the exporter must not escape
        // the escapes again.
        let mut s = TelemetrySnapshot::default();
        let key = labeled("reqs_total", &[("msg", "a\nb\"c\\d")]);
        s.counters.insert(key, 7);
        let text = s.prometheus();
        assert!(
            text.contains("reqs_total{msg=\"a\\nb\\\"c\\\\d\"} 7"),
            "{text}"
        );
    }

    #[test]
    fn raw_special_characters_in_labels_are_escaped_at_export() {
        // A collector inserting a key by hand (bypassing `labeled`) with
        // a raw newline and backslash must still yield valid exposition:
        // one line per sample, specials escaped.
        let mut s = TelemetrySnapshot::default();
        s.counters.insert("raw_total{msg=\"two\nlines \\ here\"}".into(), 3);
        s.gauges.insert("g{1bad-name=\"x\"}".into(), 5);
        let text = s.prometheus();
        assert!(
            text.contains("raw_total{msg=\"two\\nlines \\\\ here\"} 3"),
            "{text}"
        );
        // Label names are sanitized into the exposition charset.
        assert!(text.contains("g{_1bad_name=\"x\"} 5"), "{text}");
        for line in text.lines() {
            let (_, value) = line.rsplit_once(' ').expect("name value");
            assert!(
                value.parse::<f64>().is_ok() || line.starts_with("# TYPE"),
                "split sample line: {line:?}"
            );
        }
    }

    #[test]
    fn unparseable_label_suffix_falls_back_to_base_series() {
        let mut s = TelemetrySnapshot::default();
        s.counters.insert("weird{not labels at all".into(), 3);
        s.counters.insert("empty{}".into(), 4);
        let text = s.prometheus();
        assert!(text.contains("weird 3"), "{text}");
        assert!(text.contains("empty 4"), "{text}");
    }
}
