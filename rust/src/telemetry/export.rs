//! Prometheus text exposition (version 0.0.4) for [`TelemetrySnapshot`].
//!
//! Registry keys already use the series syntax `base{k="v",...}` (see
//! [`super::registry::labeled`]); the exporter splits the base name off,
//! emits one `# TYPE` line per base, and for histograms expands the
//! log2 buckets into cumulative `_bucket{le="..."}` series plus `_sum`
//! and `_count`.

use std::collections::BTreeSet;
use std::fmt::Write as _;

use super::metric::bucket_upper;
use super::snapshot::TelemetrySnapshot;

/// Base metric name (before any `{labels}`) sanitized to the exposition
/// charset `[a-zA-Z0-9_:]`.
fn sanitize_base(name: &str) -> String {
    name.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
                c
            } else {
                '_'
            }
        })
        .collect()
}

/// Split a registry key into (sanitized base, label suffix incl. braces).
fn split_series(key: &str) -> (String, &str) {
    match key.find('{') {
        Some(i) => (sanitize_base(&key[..i]), &key[i..]),
        None => (sanitize_base(key), ""),
    }
}

/// Append `le="<upper>"` to an existing label suffix (`""` or `{...}`).
fn with_le(labels: &str, le: &str) -> String {
    if labels.is_empty() {
        format!("{{le=\"{le}\"}}")
    } else {
        // `{k="v"}` -> `{k="v",le="..."}`
        format!("{},le=\"{le}\"}}", &labels[..labels.len() - 1])
    }
}

impl TelemetrySnapshot {
    /// Render the snapshot as Prometheus text exposition. Deterministic:
    /// series are emitted in `BTreeMap` key order, so labeled series of
    /// one base name stay adjacent under a single `# TYPE` line.
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        let mut typed: BTreeSet<String> = BTreeSet::new();

        for (key, &v) in &self.counters {
            let (base, labels) = split_series(key);
            if typed.insert(base.clone()) {
                let _ = writeln!(out, "# TYPE {base} counter");
            }
            let _ = writeln!(out, "{base}{labels} {v}");
        }
        for (key, &v) in &self.gauges {
            let (base, labels) = split_series(key);
            if typed.insert(base.clone()) {
                let _ = writeln!(out, "# TYPE {base} gauge");
            }
            let _ = writeln!(out, "{base}{labels} {v}");
        }
        for (key, h) in &self.histograms {
            let (base, labels) = split_series(key);
            if typed.insert(base.clone()) {
                let _ = writeln!(out, "# TYPE {base} histogram");
            }
            let highest = h
                .buckets
                .iter()
                .rposition(|&c| c > 0)
                .map(|i| i + 1)
                .unwrap_or(0);
            let mut cum = 0u64;
            for (i, &c) in h.buckets.iter().enumerate().take(highest) {
                cum += c;
                let le = bucket_upper(i).to_string();
                let _ = writeln!(out, "{base}_bucket{} {cum}", with_le(labels, &le));
            }
            let _ = writeln!(out, "{base}_bucket{} {}", with_le(labels, "+Inf"), h.count);
            let _ = writeln!(out, "{base}_sum{labels} {}", h.sum);
            let _ = writeln!(out, "{base}_count{labels} {}", h.count);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::metric::Histogram;
    use crate::telemetry::registry::labeled;

    fn sample() -> TelemetrySnapshot {
        let mut s = TelemetrySnapshot::default();
        s.counters.insert("reqs_total".into(), 10);
        s.counters
            .insert(labeled("reqs_total", &[("kind", "train")]), 4);
        s.gauges.insert("queue_depth".into(), 3);
        let h = Histogram::new();
        for v in [3u64, 5, 100, 2_000] {
            h.record(v);
        }
        s.histograms.insert("lat_ns".into(), h.snapshot());
        s.histograms.insert(
            labeled("lat_ns", &[("net", "resnet32")]),
            Histogram::new().snapshot(),
        );
        s
    }

    #[test]
    fn type_line_emitted_once_per_base() {
        let text = sample().prometheus();
        assert_eq!(text.matches("# TYPE reqs_total counter").count(), 1);
        assert_eq!(text.matches("# TYPE lat_ns histogram").count(), 1);
        assert!(text.contains("# TYPE queue_depth gauge"));
        assert!(text.contains("reqs_total 10"));
        assert!(text.contains("reqs_total{kind=\"train\"} 4"));
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_capped_by_inf() {
        let text = sample().prometheus();
        // 3 and 5 share no octave boundary with 100 and 2000: buckets at
        // le=4 (count 1), le=8 (2), le=128 (3), le=4096 (4), +Inf (4).
        assert!(text.contains("lat_ns_bucket{le=\"4\"} 1"));
        assert!(text.contains("lat_ns_bucket{le=\"8\"} 2"));
        assert!(text.contains("lat_ns_bucket{le=\"128\"} 3"));
        assert!(text.contains("lat_ns_bucket{le=\"4096\"} 4"));
        assert!(text.contains("lat_ns_bucket{le=\"+Inf\"} 4"));
        assert!(text.contains("lat_ns_sum 2108"));
        assert!(text.contains("lat_ns_count 4"));
        // Empty labeled series still expose +Inf/sum/count.
        assert!(text.contains("lat_ns_bucket{net=\"resnet32\",le=\"+Inf\"} 0"));
        assert!(text.contains("lat_ns_count{net=\"resnet32\"} 0"));
    }

    #[test]
    fn exposition_lines_are_well_formed() {
        // Mini-validator: every non-comment line is `name[{labels}] value`
        // with a parseable numeric value and a sane name charset.
        let text = sample().prometheus();
        assert!(!text.is_empty());
        for line in text.lines() {
            if line.starts_with('#') {
                assert!(line.starts_with("# TYPE "), "bad comment: {line}");
                continue;
            }
            let (series, value) = line.rsplit_once(' ').expect("name value");
            assert!(
                value.parse::<f64>().is_ok() || value == "+Inf",
                "bad value in: {line}"
            );
            let name_end = series.find('{').unwrap_or(series.len());
            assert!(
                series[..name_end]
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
                "bad name in: {line}"
            );
            if name_end < series.len() {
                assert!(series.ends_with('}'), "unterminated labels: {line}");
            }
        }
    }

    #[test]
    fn base_names_are_sanitized() {
        let mut s = TelemetrySnapshot::default();
        s.counters.insert("bad.name-1".into(), 1);
        assert!(s.prometheus().contains("bad_name_1 1"));
    }
}
