//! Sampled numerics health: live swamping counts and measured-vs-theory
//! VRR, per op.
//!
//! The paper's claim is statistical — a too-narrow accumulator loses
//! partial-sum variance through *swamping* (an addend whose magnitude
//! gap to the running sum exceeds the mantissa width is absorbed
//! entirely). The solver predicts that loss a priori; this monitor
//! measures it in vivo. For 1-in-K sampled accumulations (one dot
//! product per sampled GEMM call, one call per sampled `accumulate`
//! wrapper call), [`observe`] replays the product terms through an
//! instrumented copy of the reduced-precision loop, counting swamping
//! events and collecting the reduced and exact sums into per-op
//! [`Welford`] accumulators. The ratio of their variances is the
//! *measured* VRR, exported as a ppm gauge right next to the
//! *theoretical* VRR from [`vrr::solver`](crate::vrr::solver) for the
//! same `(n, m_p, m_acc, chunk)` — theory-vs-practice drift shows up as
//! two diverging gauges in `abws metrics` and the Prometheus export.
//!
//! The sampled replay never touches the real computation: GEMM outputs
//! and accumulate results stay bit-identical whether the monitor is on
//! or off. Cost when off (or between samples) is one relaxed
//! `fetch_add` per *call*, not per MAC.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::softfloat::accumulate::exact_sum;
use crate::softfloat::format::FpFormat;
use crate::softfloat::quant::{Quantizer, Rounding};
use crate::util::stats::Welford;
use crate::vrr::solver::AccumSpec;

/// Default sampling period: one observed accumulation per K calls.
pub const DEFAULT_SAMPLE_EVERY: u64 = 64;

static HEALTH_ENABLED: AtomicBool = AtomicBool::new(true);
static SAMPLE_EVERY: AtomicU64 = AtomicU64::new(DEFAULT_SAMPLE_EVERY);
static TICKS: AtomicU64 = AtomicU64::new(0);

/// Is the health monitor enabled? It additionally requires the global
/// [`telemetry::enabled`](super::enabled) switch, so benches that turn
/// telemetry off silence this too.
#[inline]
pub fn enabled() -> bool {
    HEALTH_ENABLED.load(Ordering::Relaxed) && super::enabled()
}

/// Turn the health monitor on or off (default on; it only fires 1-in-K).
pub fn set_enabled(on: bool) {
    HEALTH_ENABLED.store(on, Ordering::Relaxed);
}

/// Set the sampling period K (clamped to ≥ 1).
pub fn set_sample_every(k: u64) {
    SAMPLE_EVERY.store(k.max(1), Ordering::Relaxed);
}

/// Should this call be sampled? One relaxed `fetch_add` when enabled;
/// true on every K-th call.
#[inline]
pub fn should_sample() -> bool {
    if !enabled() {
        return false;
    }
    let k = SAMPLE_EVERY.load(Ordering::Relaxed).max(1);
    TICKS.fetch_add(1, Ordering::Relaxed) % k == 0
}

/// The global sample tick, for callers that want to vary *which* dot
/// they sample (e.g. the GEMM picks `(tick % m, tick % n)`).
pub fn sample_tick() -> u64 {
    TICKS.load(Ordering::Relaxed)
}

/// Accumulated health state for one op label.
#[derive(Clone, Debug, Default)]
pub struct HealthStats {
    /// Reduced-precision sums of the sampled accumulations.
    pub reduced: Welford,
    /// Exact (Neumaier) sums of the same term vectors.
    pub exact: Welford,
    /// Steps where the addend was fully absorbed (exponent gap >
    /// `m_acc`), summed over all sampled accumulations.
    pub swamping_events: u64,
    /// Sampled accumulations observed.
    pub samples: u64,
    /// Last-seen shape, for the theory-side VRR gauge.
    pub m_acc: u32,
    pub m_p: Option<u32>,
    pub n: usize,
    pub chunk: Option<usize>,
}

impl HealthStats {
    /// Measured VRR: Var(reduced) / Var(exact) over the sampled sums.
    /// `None` until there are ≥ 2 samples with nonzero exact variance.
    pub fn measured_vrr(&self) -> Option<f64> {
        if self.samples < 2 {
            return None;
        }
        let ve = self.exact.sample_variance();
        if !(ve.is_finite() && ve > 0.0) {
            return None;
        }
        Some(self.reduced.sample_variance() / ve)
    }

    /// Theoretical VRR from the solver for the last-seen shape. `None`
    /// when the product mantissa width is unknown (plain `accumulate`
    /// calls outside a GEMM don't know their terms' provenance).
    pub fn theory_vrr(&self) -> Option<f64> {
        let m_p = self.m_p?;
        if self.n == 0 {
            return None;
        }
        let spec = AccumSpec {
            n: self.n,
            m_p,
            nzr: 1.0,
            chunk: self.chunk,
        };
        Some(spec.vrr(self.m_acc))
    }
}

struct MonitorState {
    per_op: Mutex<BTreeMap<String, HealthStats>>,
}

fn state() -> &'static MonitorState {
    static STATE: OnceLock<MonitorState> = OnceLock::new();
    STATE.get_or_init(|| {
        // Gauges/counters are derived at snapshot time from the state
        // map — the hot path never touches the metrics registry.
        super::register_collector(std::sync::Arc::new(|snap| {
            for (op, st) in state().per_op.lock().unwrap().iter() {
                let labels = &[("op", op.as_str())];
                snap.counters.insert(
                    super::labeled("abws_health_sampled_accums_total", labels),
                    st.samples,
                );
                snap.counters.insert(
                    super::labeled("abws_health_swamping_events_total", labels),
                    st.swamping_events,
                );
                if let Some(v) = st.measured_vrr() {
                    snap.gauges.insert(
                        super::labeled("abws_health_measured_vrr_ppm", labels),
                        (v * 1e6).round() as i64,
                    );
                }
                if let Some(v) = st.theory_vrr() {
                    snap.gauges.insert(
                        super::labeled("abws_health_theory_vrr_ppm", labels),
                        (v * 1e6).round() as i64,
                    );
                }
            }
        }));
        MonitorState {
            per_op: Mutex::new(BTreeMap::new()),
        }
    })
}

/// Biased exponent of `x` as a signed power of two (subnormals and zero
/// collapse to the minimum — they can only be swamped, never swamp).
#[inline]
fn exp2_of(x: f64) -> i32 {
    ((x.abs().to_bits() >> 52) & 0x7ff) as i32 - 1023
}

/// Replay `terms` through an instrumented copy of the reduced-precision
/// accumulation, counting swamping events: steps where both operands are
/// nonzero and `exp(sum) - exp(term) > m_acc`, the regime where the
/// addend's entire mantissa falls off the accumulator's right edge.
fn replay(terms: &[f64], q: &Quantizer, m_acc: u32, chunk: Option<usize>) -> (f64, u64) {
    let mut swamps = 0u64;
    let mut run = |block: &[f64], mut s: f64| -> f64 {
        for &t in block {
            if t != 0.0 && s != 0.0 && exp2_of(s) - exp2_of(t) > m_acc as i32 {
                swamps += 1;
            }
            s = q.quantize(s + t);
        }
        s
    };
    let reduced = match chunk {
        None | Some(0) => run(terms, 0.0),
        Some(c) => {
            let partials: Vec<f64> = terms.chunks(c).map(|b| run(b, 0.0)).collect();
            run(&partials, 0.0)
        }
    };
    (reduced, swamps)
}

/// Observe one sampled accumulation: `terms` are the (already
/// product-quantized) addends, `acc`/`mode` the accumulator format, and
/// `m_p` the product mantissa width when known (enables the theory-VRR
/// gauge). Call only after [`should_sample`] returned true.
pub fn observe(
    op: &str,
    terms: &[f64],
    acc: FpFormat,
    mode: Rounding,
    m_p: Option<u32>,
    chunk: Option<usize>,
) {
    if terms.is_empty() {
        return;
    }
    let q = Quantizer::new(acc, mode);
    let (reduced, swamps) = replay(terms, &q, acc.man_bits, chunk);
    let exact = exact_sum(terms);
    let mut map = state().per_op.lock().unwrap();
    let st = map.entry(op.to_string()).or_default();
    st.reduced.push(reduced);
    st.exact.push(exact);
    st.swamping_events += swamps;
    st.samples += 1;
    st.m_acc = acc.man_bits;
    st.m_p = m_p.or(st.m_p);
    st.n = terms.len();
    st.chunk = chunk;
}

/// Current per-op health stats (cloned), keyed by op label.
pub fn stats() -> BTreeMap<String, HealthStats> {
    state().per_op.lock().unwrap().clone()
}

/// Drop all per-op state (test isolation).
pub fn reset() {
    state().per_op.lock().unwrap().clear();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::softfloat::accumulate::sequential_sum;

    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn replay_matches_production_accumulation() {
        // The instrumented replay must agree bit-for-bit with the real
        // reduced-precision sum — otherwise the measured VRR is fiction.
        let acc = FpFormat::accumulator(10);
        let mut rng = crate::util::rng::Pcg64::seeded(5);
        let terms: Vec<f64> = (0..512).map(|_| rng.normal()).collect();
        let q = Quantizer::new(acc, Rounding::NearestEven);
        let (reduced, _) = replay(&terms, &q, acc.man_bits, None);
        assert_eq!(
            reduced.to_bits(),
            sequential_sum(&terms, acc, Rounding::NearestEven).to_bits()
        );
    }

    #[test]
    fn narrow_accumulator_swamps_wide_does_not() {
        let _g = LOCK.lock().unwrap();
        let mut rng = crate::util::rng::Pcg64::seeded(6);
        let terms: Vec<f64> = (0..4096).map(|_| rng.normal()).collect();
        let q_narrow = Quantizer::new(FpFormat::accumulator(4), Rounding::NearestEven);
        let (_, swamps_narrow) = replay(&terms, &q_narrow, 4, None);
        let q_wide = Quantizer::new(FpFormat::accumulator(52), Rounding::NearestEven);
        let (_, swamps_wide) = replay(&terms, &q_wide, 52, None);
        assert!(
            swamps_narrow > 0,
            "m_acc=4 over n=4096 must swamp (got {swamps_narrow})"
        );
        assert_eq!(swamps_wide, 0, "f64-width accumulator must not swamp");
    }

    #[test]
    fn observe_exports_gauges_through_collector() {
        let _g = LOCK.lock().unwrap();
        let _t = super::super::TEST_ENABLED_LOCK.lock().unwrap();
        reset();
        let mut rng = crate::util::rng::Pcg64::seeded(7);
        let acc = FpFormat::accumulator(8);
        for _ in 0..8 {
            let terms: Vec<f64> = (0..256).map(|_| rng.normal()).collect();
            observe("unit_test_op", &terms, acc, Rounding::NearestEven, Some(5), None);
        }
        let st = stats();
        let s = &st["unit_test_op"];
        assert_eq!(s.samples, 8);
        assert!(s.measured_vrr().is_some());
        let theory = s.theory_vrr().unwrap();
        assert!(theory > 0.0 && theory <= 1.0 + 1e-9);
        let snap = super::super::snapshot();
        let key = super::super::labeled(
            "abws_health_sampled_accums_total",
            &[("op", "unit_test_op")],
        );
        assert_eq!(snap.counters[&key], 8);
        let vkey =
            super::super::labeled("abws_health_measured_vrr_ppm", &[("op", "unit_test_op")]);
        assert!(snap.gauges.contains_key(&vkey));
        reset();
    }

    #[test]
    fn sampling_period_is_respected() {
        let _g = LOCK.lock().unwrap();
        let _t = super::super::TEST_ENABLED_LOCK.lock().unwrap();
        super::super::set_enabled(true);
        set_sample_every(4);
        let hits = (0..16).filter(|_| should_sample()).count();
        set_sample_every(DEFAULT_SAMPLE_EVERY);
        assert_eq!(hits, 4);
    }
}
