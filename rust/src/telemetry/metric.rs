//! The three metric primitives: monotonic [`Counter`], signed [`Gauge`],
//! and a log2-bucketed [`Histogram`] with quantile extraction.
//!
//! All three are a handful of relaxed atomics — safe to hammer from the
//! solver/cache/Monte-Carlo hot paths without locks. Histograms bucket by
//! `floor(log2(value))`, which for nanosecond latencies gives ~2x
//! resolution across the full `u64` range in a fixed 64-slot table; the
//! [`HistogramSnapshot::quantile`] extraction interpolates linearly
//! inside the hit bucket, so a reported p99 is within one octave of the
//! true value.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

/// Number of log2 buckets in a [`Histogram`] (covers the full `u64`
/// value range: bucket `i` holds values in `[2^i, 2^(i+1))`, bucket 0
/// additionally holds 0).
pub const BUCKETS: usize = 64;

/// Monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter::default()
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed value (queue depths, table sizes).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge::default()
    }

    #[inline]
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn dec(&self) {
        self.add(-1);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Bucket index of a value: `floor(log2(value))`, with 0 mapping into
/// bucket 0.
#[inline]
pub(crate) fn bucket_index(value: u64) -> usize {
    if value == 0 {
        0
    } else {
        63 - value.leading_zeros() as usize
    }
}

/// Inclusive-style upper bound of bucket `i` for exposition (`le` label):
/// every value in bucket `i` is strictly below `2^(i+1)`.
pub(crate) fn bucket_upper(i: usize) -> u64 {
    if i >= BUCKETS - 1 {
        u64::MAX
    } else {
        1u64 << (i + 1)
    }
}

/// Log2-bucketed histogram of `u64` values (typically nanoseconds).
#[derive(Debug)]
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; BUCKETS],
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    #[inline]
    pub fn record(&self, value: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
        self.buckets[bucket_index(value)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record a [`std::time::Duration`] as nanoseconds (saturating past
    /// `u64::MAX` ns ≈ 584 years).
    #[inline]
    pub fn record_duration(&self, d: std::time::Duration) {
        self.record(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Point-in-time copy of the whole distribution.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
        }
    }
}

/// Immutable copy of a [`Histogram`]'s state — what snapshots, diffs and
/// exporters operate on.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: u64,
    /// `BUCKETS` entries; `buckets[i]` counts values in `[2^i, 2^(i+1))`.
    pub buckets: Vec<u64>,
}

impl HistogramSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Quantile `q ∈ [0, 1]` with linear interpolation inside the hit
    /// bucket (accurate to within one octave). NaN on an empty histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let before = cum;
            cum += c;
            if cum as f64 >= rank {
                let lo = if i == 0 { 0.0 } else { (1u64 << i) as f64 };
                let hi = bucket_upper(i) as f64;
                let frac = (rank - before as f64) / c as f64;
                return lo + frac * (hi - lo);
            }
        }
        bucket_upper(BUCKETS - 1) as f64
    }

    /// Element-wise `self - baseline` (saturating) — the per-phase delta
    /// used by bench reporting.
    pub fn diff(&self, baseline: &HistogramSnapshot) -> HistogramSnapshot {
        let buckets = self
            .buckets
            .iter()
            .enumerate()
            .map(|(i, &b)| b.saturating_sub(baseline.buckets.get(i).copied().unwrap_or(0)))
            .collect();
        HistogramSnapshot {
            count: self.count.saturating_sub(baseline.count),
            sum: self.sum.saturating_sub(baseline.sum),
            buckets,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.set(3);
        g.inc();
        g.dec();
        g.add(-5);
        assert_eq!(g.get(), -2);
    }

    #[test]
    fn bucket_index_is_floor_log2() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 1);
        assert_eq!(bucket_index(1024), 10);
        assert_eq!(bucket_index(u64::MAX), 63);
        assert_eq!(bucket_upper(0), 2);
        assert_eq!(bucket_upper(10), 2048);
        assert_eq!(bucket_upper(63), u64::MAX);
    }

    #[test]
    fn histogram_counts_and_mean() {
        let h = Histogram::new();
        for v in [100u64, 200, 400, 800] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 4);
        assert_eq!(s.sum, 1500);
        assert_eq!(s.mean(), 375.0);
        // Each value landed in its own octave.
        assert_eq!(s.buckets.iter().filter(|&&b| b > 0).count(), 4);
    }

    #[test]
    fn quantiles_bracket_the_data() {
        let h = Histogram::new();
        // 99 fast ops, one slow outlier.
        for _ in 0..99 {
            h.record(1_000);
        }
        h.record(1_000_000);
        let s = h.snapshot();
        let p50 = s.quantile(0.5);
        let p99 = s.quantile(0.99);
        let p999 = s.quantile(0.999);
        assert!((512.0..=2048.0).contains(&p50), "p50={p50}");
        assert!((512.0..=2048.0).contains(&p99), "p99={p99}");
        assert!(p999 > 500_000.0, "p99.9={p999}");
        assert!(s.quantile(0.0) <= p50);
    }

    #[test]
    fn record_duration_is_nanoseconds() {
        let h = Histogram::new();
        h.record_duration(std::time::Duration::from_micros(3));
        let s = h.snapshot();
        assert_eq!(s.count, 1);
        assert_eq!(s.sum, 3_000);
    }

    #[test]
    fn empty_histogram_quantile_is_nan() {
        let s = Histogram::new().snapshot();
        assert!(s.quantile(0.5).is_nan());
        assert!(s.mean().is_nan());
    }

    #[test]
    fn snapshot_diff_subtracts() {
        let h = Histogram::new();
        h.record(10);
        let before = h.snapshot();
        h.record(10);
        h.record(1 << 20);
        let delta = h.snapshot().diff(&before);
        assert_eq!(delta.count, 2);
        assert_eq!(delta.sum, 10 + (1 << 20));
        assert_eq!(delta.buckets[bucket_index(10)], 1);
        assert_eq!(delta.buckets[20], 1);
    }
}
