//! Zero-dependency metrics & tracing for the advisory stack.
//!
//! The paper's accumulation bounds are statistical claims; operating them
//! as a service means watching the system, not just proving it once.
//! This module is the measurement substrate: a process-wide, lock-sharded
//! [`Registry`] of [`Counter`]s, [`Gauge`]s and log2-bucketed
//! [`Histogram`]s, RAII [`Span`]s over `std::time::Instant`, and a
//! [`TelemetrySnapshot`] that diffs (per-phase bench deltas) and exports
//! as strict `util::json` or Prometheus text exposition.
//!
//! Design rules, in order:
//!
//! 1. **Hot paths pay relaxed atomics only.** Metric handles are `Arc`s
//!    resolved once (stash them in a `OnceLock`); recording is then a
//!    couple of `fetch_add(Relaxed)`s. Subsystems that already keep their
//!    own atomics (the solve cache) export them through a snapshot-time
//!    *collector* instead of double-counting on the hot path.
//! 2. **Disabled means skipped.** [`enabled`] is a single relaxed load;
//!    instrumented callsites branch on it and do nothing else when off.
//!    Telemetry is on by default — the `--telemetry` CLI flags only
//!    control *emission*.
//! 3. **Exports are deterministic.** Snapshots use `BTreeMap`s, so JSON
//!    and Prometheus output have stable ordering, same as the repo's
//!    golden-file conventions.
//!
//! ```
//! use abws::telemetry;
//!
//! let before = telemetry::snapshot();
//! telemetry::counter("demo_requests_total").inc();
//! let _span = telemetry::span::Span::enter(telemetry::histogram("demo_latency_ns"));
//! drop(_span);
//! let delta = telemetry::snapshot().diff(&before);
//! assert_eq!(delta.counters["demo_requests_total"], 1);
//! ```
//!
//! Two sibling layers build on the registry:
//!
//! - [`trace`] — request-scoped span trees with deterministic PCG ids, a
//!   lock-sharded flight recorder, and chrome://tracing export (`abws
//!   trace`, `abws serve --trace-out`, automatic dumps on request
//!   timeout/panic). Off by default; see `docs/tracing.md`.
//! - [`health`] — a 1-in-K sampled numerics monitor inside the GEMM and
//!   `accumulate` wrappers that counts swamping events and exposes
//!   measured-vs-theoretical VRR gauges per op.
//!
//! The full metrics catalog is documented in `docs/telemetry.md`.

pub mod export;
pub mod health;
pub mod metric;
pub mod registry;
pub mod snapshot;
pub mod span;
pub mod trace;

pub use metric::{Counter, Gauge, Histogram, HistogramSnapshot};
pub use registry::{labeled, Collector, Registry};
pub use snapshot::TelemetrySnapshot;
pub use span::{time, Span, Timer};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};

/// Global recording switch. Default **on**: recording costs relaxed
/// atomics, and the serve/CLI `--telemetry` flags gate emission, not
/// collection. Benches flip this off to measure instrumentation overhead.
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Is telemetry recording enabled? One relaxed load — cheap enough to
/// check on any hot path.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn recording on or off process-wide.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// The process-wide registry all instrumented subsystems report into.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

/// Get or register a counter in the global registry.
pub fn counter(name: &str) -> Arc<Counter> {
    global().counter(name)
}

/// Get or register a gauge in the global registry.
pub fn gauge(name: &str) -> Arc<Gauge> {
    global().gauge(name)
}

/// Get or register a histogram in the global registry.
pub fn histogram(name: &str) -> Arc<Histogram> {
    global().histogram(name)
}

/// Register a snapshot-time collector with the global registry.
pub fn register_collector(c: Collector) {
    global().register_collector(c);
}

/// Snapshot the global registry (registered metrics + collectors).
pub fn snapshot() -> TelemetrySnapshot {
    global().snapshot()
}

/// `ENABLED` is process-global; unit tests that flip it (or assert on
/// behaviour that depends on it) serialize on this lock so the parallel
/// test runner can't interleave them.
#[cfg(test)]
pub(crate) static TEST_ENABLED_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_registry_round_trips() {
        counter("telemetry_mod_test_total").add(3);
        gauge("telemetry_mod_test_gauge").set(9);
        histogram("telemetry_mod_test_ns").record(128);
        let s = snapshot();
        assert!(s.counters["telemetry_mod_test_total"] >= 3);
        assert_eq!(s.gauges["telemetry_mod_test_gauge"], 9);
        assert!(s.histograms["telemetry_mod_test_ns"].count >= 1);
    }

    #[test]
    fn disabled_span_records_nothing() {
        let _guard = TEST_ENABLED_LOCK.lock().unwrap();
        set_enabled(false);
        let h = histogram("telemetry_mod_disabled_ns");
        let n0 = h.count();
        drop(Span::enter(h.clone()));
        let n1 = h.count();
        set_enabled(true);
        assert!(enabled());
        assert_eq!(n0, n1);
    }
}
