//! Lock-sharded metrics registry: names in, shared metric handles out.
//!
//! Metric handles are `Arc`s — callsites that care about hot-path cost
//! resolve a handle once (e.g. in a `OnceLock`) and then touch only
//! relaxed atomics; callsites on request granularity just look up by
//! name each time (one short shard-lock + hash lookup). Subsystems that
//! already keep their own atomic counters (like the solve cache) can
//! register a *collector* instead, which contributes values at snapshot
//! time with zero hot-path cost.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::metric::{Counter, Gauge, Histogram};
use super::snapshot::TelemetrySnapshot;

/// Number of independent shards (keyed by a hash of the metric name), so
/// concurrent registrations/lookups of unrelated metrics don't contend.
const SHARD_COUNT: usize = 8;

/// A snapshot-time contributor for subsystems with pre-existing atomics.
pub type Collector = Arc<dyn Fn(&mut TelemetrySnapshot) + Send + Sync>;

#[derive(Default)]
struct Shard {
    counters: Mutex<HashMap<String, Arc<Counter>>>,
    gauges: Mutex<HashMap<String, Arc<Gauge>>>,
    histograms: Mutex<HashMap<String, Arc<Histogram>>>,
}

/// The registry: get-or-create metric handles by name, snapshot the
/// whole catalog.
pub struct Registry {
    shards: [Shard; SHARD_COUNT],
    collectors: Mutex<Vec<Collector>>,
}

impl Default for Registry {
    fn default() -> Registry {
        Registry {
            shards: std::array::from_fn(|_| Shard::default()),
            collectors: Mutex::new(Vec::new()),
        }
    }
}

/// FNV-1a — tiny, good enough to spread names over 8 shards.
fn shard_index(name: &str) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    (h as usize) % SHARD_COUNT
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    fn shard(&self, name: &str) -> &Shard {
        &self.shards[shard_index(name)]
    }

    /// Get or register the counter `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = self.shard(name).counters.lock().unwrap();
        match map.get(name) {
            Some(c) => c.clone(),
            None => {
                let c = Arc::new(Counter::new());
                map.insert(name.to_string(), c.clone());
                c
            }
        }
    }

    /// Get or register the gauge `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut map = self.shard(name).gauges.lock().unwrap();
        match map.get(name) {
            Some(g) => g.clone(),
            None => {
                let g = Arc::new(Gauge::new());
                map.insert(name.to_string(), g.clone());
                g
            }
        }
    }

    /// Get or register the histogram `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut map = self.shard(name).histograms.lock().unwrap();
        match map.get(name) {
            Some(h) => h.clone(),
            None => {
                let h = Arc::new(Histogram::new());
                map.insert(name.to_string(), h.clone());
                h
            }
        }
    }

    /// Register a snapshot-time collector. Collectors run *after* the
    /// registered metrics are copied, outside any registry lock, so they
    /// may freely call back into the registry (or into lazily-initialized
    /// globals) without deadlocking.
    pub fn register_collector(&self, c: Collector) {
        self.collectors.lock().unwrap().push(c);
    }

    /// Point-in-time copy of every registered metric plus collector
    /// contributions.
    pub fn snapshot(&self) -> TelemetrySnapshot {
        let mut snap = TelemetrySnapshot::default();
        for shard in &self.shards {
            for (name, c) in shard.counters.lock().unwrap().iter() {
                snap.counters.insert(name.clone(), c.get());
            }
            for (name, g) in shard.gauges.lock().unwrap().iter() {
                snap.gauges.insert(name.clone(), g.get());
            }
            for (name, h) in shard.histograms.lock().unwrap().iter() {
                snap.histograms.insert(name.clone(), h.snapshot());
            }
        }
        // Clone the collector list first so none of the registry locks
        // are held while user code runs.
        let collectors: Vec<Collector> = self.collectors.lock().unwrap().clone();
        for c in &collectors {
            c(&mut snap);
        }
        snap
    }
}

/// Build a labeled metric name, `base{k="v",...}` — the exposition-format
/// series syntax, understood by the Prometheus exporter. Label values are
/// escaped per the exposition rules.
pub fn labeled(base: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return base.to_string();
    }
    let mut s = String::with_capacity(base.len() + 16 * labels.len());
    s.push_str(base);
    s.push('{');
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(k);
        s.push_str("=\"");
        for ch in v.chars() {
            match ch {
                '\\' => s.push_str("\\\\"),
                '"' => s.push_str("\\\""),
                '\n' => s.push_str("\\n"),
                c => s.push(c),
            }
        }
        s.push('"');
    }
    s.push('}');
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_name_same_handle() {
        let r = Registry::new();
        let a = r.counter("x_total");
        let b = r.counter("x_total");
        a.inc();
        b.add(2);
        assert_eq!(r.counter("x_total").get(), 3);
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn kinds_do_not_collide() {
        let r = Registry::new();
        r.counter("m").inc();
        r.gauge("m").set(-7);
        r.histogram("m").record(5);
        let s = r.snapshot();
        assert_eq!(s.counters.get("m"), Some(&1));
        assert_eq!(s.gauges.get("m"), Some(&-7));
        assert_eq!(s.histograms.get("m").unwrap().count, 1);
    }

    #[test]
    fn snapshot_covers_all_shards() {
        let r = Registry::new();
        for i in 0..64 {
            r.counter(&format!("metric_{i}_total")).add(i);
        }
        let s = r.snapshot();
        assert_eq!(s.counters.len(), 64);
        assert_eq!(s.counters["metric_63_total"], 63);
    }

    #[test]
    fn collectors_contribute_at_snapshot_time() {
        let r = Registry::new();
        r.register_collector(Arc::new(|snap| {
            snap.counters.insert("derived_total".into(), 42);
        }));
        assert_eq!(r.snapshot().counters.get("derived_total"), Some(&42));
    }

    #[test]
    fn labeled_builds_series_names() {
        assert_eq!(labeled("x_total", &[]), "x_total");
        assert_eq!(
            labeled("x_total", &[("net", "resnet32"), ("gemm", "FWD")]),
            "x_total{net=\"resnet32\",gemm=\"FWD\"}"
        );
        assert_eq!(labeled("x", &[("k", "a\"b")]), "x{k=\"a\\\"b\"}");
    }

    #[test]
    fn concurrent_registration_is_safe() {
        let r = Registry::new();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for i in 0..100 {
                        r.counter(&format!("c{}_total", i % 10)).inc();
                    }
                });
            }
        });
        let s = r.snapshot();
        let total: u64 = s.counters.values().sum();
        assert_eq!(total, 400);
        assert_eq!(s.counters.len(), 10);
    }
}
