//! [`TelemetrySnapshot`]: a point-in-time copy of the whole metrics
//! catalog, with diffing (for per-phase bench deltas), JSON export and a
//! human-readable table rendering.

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::metric::{bucket_upper, HistogramSnapshot};

/// Everything the registry knew at one instant. `BTreeMap`s keep every
/// export deterministic (stable name order), matching the repo's
/// golden-file conventions.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TelemetrySnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub histograms: BTreeMap<String, HistogramSnapshot>,
}

impl TelemetrySnapshot {
    /// `self - baseline`: counters and histograms subtract (saturating);
    /// gauges are instantaneous so the later value is kept as-is.
    /// Metrics absent from the baseline pass through unchanged — this is
    /// the "what did this phase do" primitive benches report with.
    pub fn diff(&self, baseline: &TelemetrySnapshot) -> TelemetrySnapshot {
        let counters = self
            .counters
            .iter()
            .map(|(k, &v)| {
                let base = baseline.counters.get(k).copied().unwrap_or(0);
                (k.clone(), v.saturating_sub(base))
            })
            .collect();
        let histograms = self
            .histograms
            .iter()
            .map(|(k, h)| {
                let delta = match baseline.histograms.get(k) {
                    Some(base) => h.diff(base),
                    None => h.clone(),
                };
                (k.clone(), delta)
            })
            .collect();
        TelemetrySnapshot {
            counters,
            gauges: self.gauges.clone(),
            histograms,
        }
    }

    /// Strict `util::json` export. Histograms carry summary statistics
    /// (count/sum/mean/p50/p95/p99) plus the raw non-empty buckets as
    /// `[upper_bound, count]` pairs.
    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (name, &v) in &self.counters {
            counters.set(name, v);
        }
        let mut gauges = Json::obj();
        for (name, &v) in &self.gauges {
            gauges.set(name, v);
        }
        let mut histograms = Json::obj();
        for (name, h) in &self.histograms {
            let mut entry = Json::obj();
            entry
                .set("count", h.count)
                .set("sum", h.sum)
                .set("mean", h.mean())
                .set("p50", h.quantile(0.50))
                .set("p95", h.quantile(0.95))
                .set("p99", h.quantile(0.99));
            let buckets: Vec<Json> = h
                .buckets
                .iter()
                .enumerate()
                .filter(|(_, &c)| c > 0)
                .map(|(i, &c)| Json::Arr(vec![Json::from(bucket_upper(i)), Json::from(c)]))
                .collect();
            entry.set("buckets", buckets);
            histograms.set(name, entry);
        }
        let mut root = Json::obj();
        root.set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", histograms);
        root
    }

    /// Human-readable table for `abws metrics`.
    pub fn render(&self) -> String {
        fn fmt_ns(ns: f64) -> String {
            if ns.is_nan() {
                "-".to_string()
            } else if ns >= 1e9 {
                format!("{:.2}s", ns / 1e9)
            } else if ns >= 1e6 {
                format!("{:.2}ms", ns / 1e6)
            } else if ns >= 1e3 {
                format!("{:.2}us", ns / 1e3)
            } else {
                format!("{ns:.0}ns")
            }
        }
        let mut out = String::new();
        if !self.counters.is_empty() {
            out.push_str("counters:\n");
            let width = self.counters.keys().map(|k| k.len()).max().unwrap_or(0);
            for (name, v) in &self.counters {
                out.push_str(&format!("  {name:<width$}  {v}\n"));
            }
        }
        if !self.gauges.is_empty() {
            out.push_str("gauges:\n");
            let width = self.gauges.keys().map(|k| k.len()).max().unwrap_or(0);
            for (name, v) in &self.gauges {
                out.push_str(&format!("  {name:<width$}  {v}\n"));
            }
        }
        if !self.histograms.is_empty() {
            out.push_str("histograms:\n");
            // Aligned columns: quantiles come from the log2 buckets, so
            // p50/p95/p99 are bucket-upper-bound estimates.
            let rows: Vec<(&String, [String; 5])> = self
                .histograms
                .iter()
                .map(|(name, h)| {
                    // `_ns`-suffixed histograms hold nanoseconds — humanize.
                    let time_like = name.contains("_ns");
                    let fmt = |x: f64| {
                        if time_like {
                            fmt_ns(x)
                        } else if x.is_nan() {
                            "-".to_string()
                        } else {
                            format!("{x:.1}")
                        }
                    };
                    let cells = [
                        h.count.to_string(),
                        fmt(h.mean()),
                        fmt(h.quantile(0.50)),
                        fmt(h.quantile(0.95)),
                        fmt(h.quantile(0.99)),
                    ];
                    (name, cells)
                })
                .collect();
            let headers = ["count", "mean", "p50", "p95", "p99"];
            let name_w = rows
                .iter()
                .map(|(n, _)| n.len())
                .max()
                .unwrap_or(0)
                .max("name".len());
            let mut col_w = headers.map(str::len);
            for (_, cells) in &rows {
                for (w, c) in col_w.iter_mut().zip(cells) {
                    *w = (*w).max(c.len());
                }
            }
            out.push_str(&format!("  {:<name_w$}", "name"));
            for (h, w) in headers.iter().zip(col_w) {
                out.push_str(&format!("  {h:>w$}"));
            }
            out.push('\n');
            for (name, cells) in &rows {
                out.push_str(&format!("  {name:<name_w$}"));
                for (c, w) in cells.iter().zip(col_w) {
                    out.push_str(&format!("  {c:>w$}"));
                }
                out.push('\n');
            }
        }
        if out.is_empty() {
            out.push_str("(no metrics recorded)\n");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::metric::Histogram;

    fn sample() -> TelemetrySnapshot {
        let h = Histogram::new();
        for v in [100u64, 200, 400] {
            h.record(v);
        }
        let mut s = TelemetrySnapshot::default();
        s.counters.insert("reqs_total".into(), 10);
        s.gauges.insert("depth".into(), -2);
        s.histograms.insert("lat_ns".into(), h.snapshot());
        s
    }

    #[test]
    fn json_export_has_quantiles_and_buckets() {
        let j = sample().to_json();
        assert_eq!(
            j.get("counters").unwrap().get("reqs_total").unwrap().as_f64(),
            Some(10.0)
        );
        assert_eq!(
            j.get("gauges").unwrap().get("depth").unwrap().as_f64(),
            Some(-2.0)
        );
        let h = j.get("histograms").unwrap().get("lat_ns").unwrap();
        assert_eq!(h.get("count").unwrap().as_f64(), Some(3.0));
        assert_eq!(h.get("sum").unwrap().as_f64(), Some(700.0));
        assert!(h.get("p50").unwrap().as_f64().unwrap() > 0.0);
        assert!(h.get("p99").unwrap().as_f64().is_some());
        assert_eq!(h.get("buckets").unwrap().as_arr().unwrap().len(), 3);
        // The export is valid JSON text.
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn diff_subtracts_counters_and_histograms() {
        let before = sample();
        let mut after = before.clone();
        *after.counters.get_mut("reqs_total").unwrap() = 25;
        after.counters.insert("new_total".into(), 7);
        let h = Histogram::new();
        for v in [100u64, 200, 400, 800, 1600] {
            h.record(v);
        }
        *after.histograms.get_mut("lat_ns").unwrap() = h.snapshot();
        let d = after.diff(&before);
        assert_eq!(d.counters["reqs_total"], 15);
        assert_eq!(d.counters["new_total"], 7);
        assert_eq!(d.histograms["lat_ns"].count, 2);
        assert_eq!(d.gauges["depth"], -2);
    }

    #[test]
    fn render_histogram_table_has_percentile_columns() {
        let text = sample().render();
        let lines: Vec<&str> = text.lines().collect();
        let hi = lines
            .iter()
            .position(|l| l.starts_with("histograms:"))
            .unwrap();
        let header = lines[hi + 1];
        for col in ["name", "count", "mean", "p50", "p95", "p99"] {
            assert!(header.contains(col), "missing {col} in {header:?}");
        }
        // One row per histogram: name then the five stat cells.
        let toks: Vec<&str> = lines[hi + 2].split_whitespace().collect();
        assert_eq!(toks.len(), 6, "{:?}", lines[hi + 2]);
        assert_eq!(toks[0], "lat_ns");
        assert_eq!(toks[1], "3");
    }

    #[test]
    fn render_mentions_each_metric() {
        let text = sample().render();
        assert!(text.contains("reqs_total"));
        assert!(text.contains("depth"));
        assert!(text.contains("lat_ns"));
        assert!(text.contains("p95"));
        assert!(TelemetrySnapshot::default().render().contains("no metrics"));
    }
}
