//! Scoped timers: measure a region's wall time and record it into a
//! [`Histogram`] on drop.
//!
//! Two flavors: [`Timer`] is an explicit start/stop stopwatch for code
//! that wants the raw nanoseconds, [`Span`] is an RAII guard that records
//! into a histogram when it leaves scope (including on early return and
//! `?` propagation). Both are no-ops costing one branch when telemetry is
//! globally disabled.

use std::sync::Arc;
use std::time::Instant;

use super::metric::Histogram;

/// Explicit stopwatch over `std::time::Instant`.
#[derive(Clone, Copy, Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    #[inline]
    pub fn start() -> Timer {
        Timer {
            start: Instant::now(),
        }
    }

    /// Elapsed wall time in nanoseconds (saturating at `u64::MAX`, which
    /// at ~584 years of uptime is not a practical concern).
    #[inline]
    pub fn elapsed_ns(&self) -> u64 {
        u64::try_from(self.start.elapsed().as_nanos()).unwrap_or(u64::MAX)
    }
}

/// RAII region timer: records elapsed nanoseconds into its histogram on
/// drop. Build with [`Span::enter`]; a span constructed while telemetry
/// is disabled (or via [`Span::noop`]) records nothing.
#[derive(Debug)]
pub struct Span {
    rec: Option<(Arc<Histogram>, Timer)>,
}

impl Span {
    /// Start timing into `hist` (no-op if telemetry is disabled).
    #[inline]
    pub fn enter(hist: Arc<Histogram>) -> Span {
        if super::enabled() {
            Span {
                rec: Some((hist, Timer::start())),
            }
        } else {
            Span::noop()
        }
    }

    /// A span that records nothing.
    #[inline]
    pub fn noop() -> Span {
        Span { rec: None }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((hist, timer)) = self.rec.take() {
            hist.record(timer.elapsed_ns());
        }
    }
}

/// Time a closure into `hist` and return its result.
#[inline]
pub fn time<T>(hist: Arc<Histogram>, f: impl FnOnce() -> T) -> T {
    let _span = Span::enter(hist);
    f()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_something() {
        let t = Timer::start();
        std::hint::black_box((0..1000).sum::<u64>());
        assert!(t.elapsed_ns() > 0);
    }

    #[test]
    fn span_records_on_drop() {
        let h = Arc::new(Histogram::new());
        {
            let _s = Span::enter(h.clone());
        }
        assert_eq!(h.count(), 1);
        {
            let _n = Span::noop();
        }
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn time_helper_returns_value() {
        let h = Arc::new(Histogram::new());
        let v = time(h.clone(), || 6 * 7);
        assert_eq!(v, 42);
        assert_eq!(h.count(), 1);
    }
}
