//! Request-scoped tracing: span trees, a flight recorder, chrome://tracing
//! export.
//!
//! The metrics side of `telemetry` answers "how much / how long, in
//! aggregate". This module answers *which*: which serve request spent its
//! deadline inside which GEMM panel, on which worker. The model is the
//! usual distributed-tracing one, collapsed to a single process:
//!
//! - A **span** is a named, timed region with a `trace_id` (shared by
//!   every span of one request tree), its own `span_id`, and a
//!   `parent_id` (0 for roots). Ids come from a PCG stream keyed by a
//!   process-wide `(seed, counter)`, so [`reseed`] makes id assignment
//!   deterministic for golden tests.
//! - The **current span** is thread-local: [`TraceSpan::enter`] pushes
//!   onto a stack, `Drop` pops and records. Crossing a thread boundary
//!   (the worker pool) uses an **ambient** context: the spawner's
//!   current span is captured once and installed on each worker via
//!   [`set_ambient`], so pool regions adopt the spawning span as parent.
//! - Completed spans land in the **flight recorder** — a lock-sharded
//!   bounded ring that keeps the last N spans and drops the oldest. It
//!   can be snapshotted (for crash/timeout dumps, ring kept) or drained
//!   (clean shutdown) and serialized as chrome://tracing JSON via
//!   [`chrome_trace_json`] — load the file at `chrome://tracing` or
//!   <https://ui.perfetto.dev>.
//!
//! Tracing is **off by default** (unlike metrics): [`enabled`] is one
//! relaxed load, and a disabled [`TraceSpan::enter`] allocates nothing
//! and touches no thread-local state. Callsites that build attribute
//! strings branch on [`enabled`] first, same as the metrics convention.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// Spans kept by the flight recorder before the oldest are dropped.
pub const RING_CAPACITY: usize = 4096;

const SHARDS: usize = 8;

// ---------------------------------------------------------------------------
// Enable switch + id generation
// ---------------------------------------------------------------------------

static TRACE_ENABLED: AtomicBool = AtomicBool::new(false);

/// Is tracing enabled? One relaxed load; hot paths branch on this before
/// building any attribute strings.
#[inline]
pub fn enabled() -> bool {
    TRACE_ENABLED.load(Ordering::Relaxed)
}

/// Turn span recording on or off process-wide. Off is the default: spans
/// are a per-request diagnostic, not an always-on aggregate.
pub fn set_enabled(on: bool) {
    TRACE_ENABLED.store(on, Ordering::Relaxed);
}

static ID_SEED: AtomicU64 = AtomicU64::new(0x0ab5_1de5);
static ID_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Reset the id generator: the `k`-th id handed out after `reseed(s)` is
/// a pure function of `(s, k)`, so a single-threaded workload replayed
/// after the same `reseed` gets identical trace/span ids.
pub fn reseed(seed: u64) {
    ID_SEED.store(seed, Ordering::Relaxed);
    ID_COUNTER.store(0, Ordering::Relaxed);
}

fn next_id() -> u64 {
    let k = ID_COUNTER.fetch_add(1, Ordering::Relaxed);
    let seed = ID_SEED.load(Ordering::Relaxed);
    // One dedicated PCG stream per counter value: ids never collide with
    // the simulation RNG streams and stay reproducible under `reseed`.
    let mut rng = Pcg64::new(seed, k);
    loop {
        let id = rng.next_u64();
        if id != 0 {
            return id; // 0 is reserved for "no parent"
        }
    }
}

// ---------------------------------------------------------------------------
// Thread-local context
// ---------------------------------------------------------------------------

/// The identity a child span attaches to: which trace, which parent.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanCtx {
    pub trace_id: u64,
    pub span_id: u64,
}

thread_local! {
    /// Open spans on this thread, innermost last.
    static STACK: RefCell<Vec<SpanCtx>> = const { RefCell::new(Vec::new()) };
    /// Cross-thread parent: what a root span on this thread adopts when
    /// the local stack is empty (set by pool workers around a job).
    static AMBIENT: Cell<Option<SpanCtx>> = const { Cell::new(None) };
    /// Small stable per-thread id for the chrome `tid` field.
    static TID: Cell<u64> = const { Cell::new(0) };
}

static TID_COUNTER: AtomicU64 = AtomicU64::new(1);

fn tid() -> u64 {
    TID.with(|t| {
        let v = t.get();
        if v != 0 {
            v
        } else {
            let v = TID_COUNTER.fetch_add(1, Ordering::Relaxed);
            t.set(v);
            v
        }
    })
}

/// The context a child span created *right now* on this thread would
/// use as its parent: the innermost open span, else the ambient context
/// installed by the worker pool, else `None` (a fresh trace root).
pub fn current() -> Option<SpanCtx> {
    STACK
        .with(|s| s.borrow().last().copied())
        .or_else(|| AMBIENT.with(|a| a.get()))
}

/// Restores the previous ambient context on drop.
pub struct AmbientGuard {
    prev: Option<SpanCtx>,
}

/// Install `ctx` as this thread's ambient parent context (RAII). The
/// worker pool wraps each claimed job in this so spans opened on the
/// worker parent onto the span that published the job.
pub fn set_ambient(ctx: Option<SpanCtx>) -> AmbientGuard {
    let prev = AMBIENT.with(|a| a.replace(ctx));
    AmbientGuard { prev }
}

impl Drop for AmbientGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        AMBIENT.with(|a| a.set(prev));
    }
}

// ---------------------------------------------------------------------------
// Spans
// ---------------------------------------------------------------------------

/// One completed span as stored in the flight recorder.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    pub name: &'static str,
    pub trace_id: u64,
    pub span_id: u64,
    /// 0 = root.
    pub parent_id: u64,
    /// Nanoseconds since the process trace epoch (first span ever).
    pub start_ns: u64,
    pub dur_ns: u64,
    /// Small stable per-thread id (chrome `tid`).
    pub tid: u64,
    pub attrs: Vec<(&'static str, String)>,
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

struct ActiveSpan {
    rec: SpanRecord,
    t0: Instant,
}

/// RAII span guard. [`TraceSpan::enter`] while tracing is disabled is a
/// single relaxed load returning an inert guard — no allocation, no
/// thread-local traffic, nothing recorded on drop.
pub struct TraceSpan {
    active: Option<Box<ActiveSpan>>,
}

impl TraceSpan {
    /// An inert guard, for the `else` arm of an `enabled()` branch.
    pub fn noop() -> TraceSpan {
        TraceSpan { active: None }
    }

    /// Open a span named `name`, parented on [`current`] (new trace root
    /// if there is none), and make it the thread's current span.
    pub fn enter(name: &'static str) -> TraceSpan {
        if !enabled() {
            return TraceSpan::noop();
        }
        let (trace_id, parent_id) = match current() {
            Some(c) => (c.trace_id, c.span_id),
            None => (next_id(), 0),
        };
        let span_id = next_id();
        STACK.with(|s| s.borrow_mut().push(SpanCtx { trace_id, span_id }));
        let ep = epoch();
        let t0 = Instant::now();
        let rec = SpanRecord {
            name,
            trace_id,
            span_id,
            parent_id,
            start_ns: t0.saturating_duration_since(ep).as_nanos() as u64,
            dur_ns: 0,
            tid: tid(),
            attrs: Vec::new(),
        };
        TraceSpan {
            active: Some(Box::new(ActiveSpan { rec, t0 })),
        }
    }

    /// Attach a `key=value` attribute (builder style). No-op when inert.
    pub fn attr(mut self, key: &'static str, value: impl Into<String>) -> TraceSpan {
        if let Some(a) = &mut self.active {
            a.rec.attrs.push((key, value.into()));
        }
        self
    }

    /// This span's context, for handing to [`set_ambient`] on another
    /// thread. `None` when inert.
    pub fn ctx(&self) -> Option<SpanCtx> {
        self.active.as_ref().map(|a| SpanCtx {
            trace_id: a.rec.trace_id,
            span_id: a.rec.span_id,
        })
    }
}

impl Drop for TraceSpan {
    fn drop(&mut self) {
        if let Some(mut a) = self.active.take() {
            a.rec.dur_ns = a.t0.elapsed().as_nanos() as u64;
            let id = a.rec.span_id;
            STACK.with(|s| {
                let mut st = s.borrow_mut();
                // RAII nesting makes our entry the top; stay correct if
                // a guard escaped its scope out of order.
                match st.last() {
                    Some(c) if c.span_id == id => {
                        st.pop();
                    }
                    _ => {
                        if let Some(i) = st.iter().rposition(|c| c.span_id == id) {
                            st.remove(i);
                        }
                    }
                }
            });
            recorder().record(a.rec);
        }
    }
}

// ---------------------------------------------------------------------------
// Flight recorder
// ---------------------------------------------------------------------------

/// Lock-sharded bounded ring of the last [`RING_CAPACITY`] completed
/// spans. Sharded by span id so concurrent pool workers rarely contend;
/// each shard drops its oldest span when full.
pub struct FlightRecorder {
    shards: Vec<Mutex<VecDeque<SpanRecord>>>,
    cap_per_shard: usize,
    recorded: AtomicU64,
    dropped: AtomicU64,
}

impl FlightRecorder {
    fn new(capacity: usize) -> FlightRecorder {
        FlightRecorder {
            shards: (0..SHARDS).map(|_| Mutex::new(VecDeque::new())).collect(),
            cap_per_shard: capacity.div_ceil(SHARDS).max(1),
            recorded: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    fn record(&self, rec: SpanRecord) {
        let shard = (rec.span_id as usize) & (SHARDS - 1);
        let mut q = self.shards[shard].lock().unwrap();
        if q.len() >= self.cap_per_shard {
            q.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        q.push_back(rec);
        drop(q);
        self.recorded.fetch_add(1, Ordering::Relaxed);
    }

    fn collect(&self, drain: bool) -> Vec<SpanRecord> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let mut q = shard.lock().unwrap();
            if drain {
                out.extend(q.drain(..));
            } else {
                out.extend(q.iter().cloned());
            }
        }
        out.sort_by_key(|r| (r.start_ns, r.span_id));
        out
    }

    /// Spans currently buffered.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total spans ever recorded (including since-dropped ones).
    pub fn recorded(&self) -> u64 {
        self.recorded.load(Ordering::Relaxed)
    }

    /// Spans evicted because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

fn recorder() -> &'static FlightRecorder {
    static RECORDER: OnceLock<FlightRecorder> = OnceLock::new();
    RECORDER.get_or_init(|| {
        // Export ring health through the metrics registry; the closure
        // only *runs* at snapshot time, well after init completes.
        super::register_collector(std::sync::Arc::new(|snap| {
            let r = recorder();
            snap.counters
                .insert("abws_trace_spans_recorded_total".into(), r.recorded());
            snap.counters
                .insert("abws_trace_spans_dropped_total".into(), r.dropped());
            snap.counters
                .insert("abws_trace_dumps_total".into(), DUMPS.load(Ordering::Relaxed));
            snap.gauges
                .insert("abws_trace_ring_spans".into(), r.len() as i64);
        }));
        FlightRecorder::new(RING_CAPACITY)
    })
}

/// Copy the buffered spans out, oldest first; the ring keeps them.
pub fn snapshot_spans() -> Vec<SpanRecord> {
    recorder().collect(false)
}

/// Move the buffered spans out, oldest first, leaving the ring empty.
pub fn drain_spans() -> Vec<SpanRecord> {
    recorder().collect(true)
}

/// Empty the ring without returning anything (test isolation).
pub fn clear() {
    drop(recorder().collect(true));
}

// ---------------------------------------------------------------------------
// chrome://tracing export + failure dumps
// ---------------------------------------------------------------------------

/// Serialize spans as the chrome trace-event format: one complete
/// (`"ph":"X"`) event per span, microsecond timestamps, span identity
/// and attributes under `args`. Events are emitted oldest-first.
pub fn chrome_trace_json(spans: &[SpanRecord]) -> Json {
    let mut sorted: Vec<&SpanRecord> = spans.iter().collect();
    sorted.sort_by_key(|r| (r.start_ns, r.span_id));
    let mut events: Vec<Json> = Vec::with_capacity(sorted.len());
    for r in sorted {
        let mut args = Json::obj();
        args.set("trace_id", format!("{:016x}", r.trace_id));
        args.set("span_id", format!("{:016x}", r.span_id));
        args.set("parent_id", format!("{:016x}", r.parent_id));
        for (k, v) in &r.attrs {
            args.set(k, v.as_str());
        }
        let mut e = Json::obj();
        e.set("name", r.name);
        e.set("cat", "abws");
        e.set("ph", "X");
        e.set("ts", r.start_ns as f64 / 1000.0);
        e.set("dur", r.dur_ns as f64 / 1000.0);
        e.set("pid", 1u64);
        e.set("tid", r.tid);
        e.set("args", args);
        events.push(e);
    }
    let mut root = Json::obj();
    root.set("traceEvents", events);
    root.set("displayTimeUnit", "ms");
    root
}

static DUMP_PATH: Mutex<Option<PathBuf>> = Mutex::new(None);
static DUMPS: AtomicU64 = AtomicU64::new(0);

/// Configure where failure dumps ([`dump_now`]) land. `None` disables
/// them. Process-global so `ServeOptions` can stay `Copy`.
pub fn set_dump_path(path: Option<PathBuf>) {
    *DUMP_PATH.lock().unwrap() = path;
}

/// Write a chrome-trace snapshot of the ring to `path`. Returns the
/// number of spans written. The ring is kept (use [`drain_to_file`] on
/// clean shutdown).
pub fn dump_to_file(path: &Path) -> std::io::Result<usize> {
    let spans = snapshot_spans();
    std::fs::write(path, chrome_trace_json(&spans).to_string())?;
    Ok(spans.len())
}

/// Drain the ring into a chrome-trace file (clean-exit flush).
pub fn drain_to_file(path: &Path) -> std::io::Result<usize> {
    let spans = drain_spans();
    std::fs::write(path, chrome_trace_json(&spans).to_string())?;
    Ok(spans.len())
}

/// Best-effort failure dump: if tracing is enabled and a dump path is
/// configured, snapshot the ring there. Called by serve when a request
/// times out or panics, so every deadline miss ships with its span
/// tree. Keeps the ring (later failures re-dump with more context).
pub fn dump_now() {
    if !enabled() {
        return;
    }
    let path = DUMP_PATH.lock().unwrap().clone();
    if let Some(p) = path {
        if dump_to_file(&p).is_ok() {
            DUMPS.fetch_add(1, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Trace state (enabled flag, ring, id counter) is process-global;
    // tests that flip it serialize here.
    static LOCK: Mutex<()> = Mutex::new(());

    fn with_trace<F: FnOnce()>(seed: u64, f: F) -> Vec<SpanRecord> {
        clear();
        reseed(seed);
        set_enabled(true);
        f();
        set_enabled(false);
        drain_spans()
    }

    #[test]
    fn disabled_enter_records_nothing() {
        let _g = LOCK.lock().unwrap();
        set_enabled(false);
        clear();
        let before = recorder().recorded();
        {
            let _s = TraceSpan::enter("never").attr("k", "v");
        }
        assert_eq!(recorder().recorded(), before);
        assert!(current().is_none());
    }

    #[test]
    fn nesting_sets_parent_ids() {
        let _g = LOCK.lock().unwrap();
        let spans = with_trace(11, || {
            let root = TraceSpan::enter("root");
            let root_ctx = root.ctx().unwrap();
            {
                let child = TraceSpan::enter("child");
                let cctx = child.ctx().unwrap();
                assert_eq!(cctx.trace_id, root_ctx.trace_id);
                let _grand = TraceSpan::enter("grandchild");
            }
            drop(root);
            assert!(current().is_none());
        });
        assert_eq!(spans.len(), 3);
        let root = spans.iter().find(|s| s.name == "root").unwrap();
        let child = spans.iter().find(|s| s.name == "child").unwrap();
        let grand = spans.iter().find(|s| s.name == "grandchild").unwrap();
        assert_eq!(root.parent_id, 0);
        assert_eq!(child.parent_id, root.span_id);
        assert_eq!(grand.parent_id, child.span_id);
        assert!(spans.iter().all(|s| s.trace_id == root.trace_id));
    }

    #[test]
    fn ambient_context_adopts_parent() {
        let _g = LOCK.lock().unwrap();
        let spans = with_trace(12, || {
            let root = TraceSpan::enter("spawner");
            let ctx = root.ctx();
            std::thread::scope(|scope| {
                scope.spawn(|| {
                    let _amb = set_ambient(ctx);
                    let _s = TraceSpan::enter("worker.child");
                });
            });
            // Ambient restored after the guard dropped on that thread;
            // this thread never saw it.
            assert_eq!(current(), ctx);
        });
        let root = spans.iter().find(|s| s.name == "spawner").unwrap();
        let child = spans.iter().find(|s| s.name == "worker.child").unwrap();
        assert_eq!(child.parent_id, root.span_id);
        assert_eq!(child.trace_id, root.trace_id);
        assert_ne!(child.tid, root.tid);
    }

    #[test]
    fn reseed_makes_ids_deterministic() {
        let _g = LOCK.lock().unwrap();
        let ids = |seed| {
            let spans = with_trace(seed, || {
                let _a = TraceSpan::enter("a");
                let _b = TraceSpan::enter("b");
            });
            spans
                .iter()
                .map(|s| (s.trace_id, s.span_id, s.parent_id))
                .collect::<Vec<_>>()
        };
        assert_eq!(ids(77), ids(77));
        assert_ne!(ids(77), ids(78));
    }

    #[test]
    fn ring_bounds_and_counts_drops() {
        let _g = LOCK.lock().unwrap();
        let spans = with_trace(13, || {
            for _ in 0..(RING_CAPACITY + 256) {
                let _s = TraceSpan::enter("filler");
            }
        });
        assert!(spans.len() <= RING_CAPACITY + SHARDS);
        assert!(recorder().dropped() > 0);
    }

    #[test]
    fn chrome_export_shape() {
        let _g = LOCK.lock().unwrap();
        let spans = with_trace(14, || {
            let _r = TraceSpan::enter("req").attr("type", "advisor");
            let _c = TraceSpan::enter("inner");
        });
        let j = chrome_trace_json(&spans);
        let events = j.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        assert_eq!(events.len(), 2);
        for e in events {
            assert_eq!(e.get("ph").and_then(|p| p.as_str()), Some("X"));
            assert!(e.get("ts").and_then(|t| t.as_f64()).is_some());
            let args = e.get("args").unwrap();
            assert!(args.get("span_id").is_some());
        }
        // Round-trips through the strict parser.
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn dump_now_writes_configured_path() {
        let _g = LOCK.lock().unwrap();
        let path =
            std::env::temp_dir().join(format!("abws_trace_dump_{}.json", std::process::id()));
        clear();
        reseed(15);
        set_enabled(true);
        set_dump_path(Some(path.clone()));
        {
            let _s = TraceSpan::enter("failing.request");
        }
        dump_now();
        set_dump_path(None);
        set_enabled(false);
        clear();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let j = Json::parse(&text).unwrap();
        assert!(!j.get("traceEvents").and_then(|e| e.as_arr()).unwrap().is_empty());
    }
}
