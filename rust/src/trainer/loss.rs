//! Softmax cross-entropy loss with analytic gradient.

use crate::softfloat::tensor::Tensor;

/// Row-wise softmax (numerically stabilized by max subtraction).
pub fn softmax(logits: &Tensor) -> Tensor {
    assert_eq!(logits.rank(), 2);
    let (b, c) = (logits.shape[0], logits.shape[1]);
    let mut out = Tensor::zeros(&[b, c]);
    for i in 0..b {
        let row = &logits.data[i * c..(i + 1) * c];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f64;
        for j in 0..c {
            denom += ((row[j] - max) as f64).exp();
        }
        for j in 0..c {
            out.data[i * c + j] = (((row[j] - max) as f64).exp() / denom) as f32;
        }
    }
    out
}

/// Mean cross-entropy of `logits` against integer `labels`, plus the
/// gradient w.r.t. the logits (`(softmax − onehot)/B`).
pub fn cross_entropy(logits: &Tensor, labels: &[usize]) -> (f64, Tensor) {
    let (b, c) = (logits.shape[0], logits.shape[1]);
    assert_eq!(labels.len(), b);
    let probs = softmax(logits);
    let mut loss = 0.0f64;
    let mut grad = probs.clone();
    for i in 0..b {
        let p = probs.data[i * c + labels[i]].max(1e-12);
        loss -= (p as f64).ln();
        grad.data[i * c + labels[i]] -= 1.0;
    }
    for g in grad.data.iter_mut() {
        *g /= b as f32;
    }
    (loss / b as f64, grad)
}

/// Top-1 accuracy of `logits` against `labels`.
pub fn accuracy(logits: &Tensor, labels: &[usize]) -> f64 {
    let (b, c) = (logits.shape[0], logits.shape[1]);
    let mut correct = 0usize;
    for i in 0..b {
        let row = &logits.data[i * c..(i + 1) * c];
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap();
        if argmax == labels[i] {
            correct += 1;
        }
    }
    correct as f64 / b as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_rows_sum_to_one() {
        let logits = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        let p = softmax(&logits);
        for i in 0..2 {
            let s: f32 = p.data[i * 3..(i + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        // Monotone: bigger logit, bigger prob.
        assert!(p.data[2] > p.data[1] && p.data[1] > p.data[0]);
    }

    #[test]
    fn softmax_is_shift_invariant_and_stable() {
        let a = softmax(&Tensor::from_vec(&[1, 3], vec![1.0, 2.0, 3.0]));
        let b = softmax(&Tensor::from_vec(&[1, 3], vec![1001.0, 1002.0, 1003.0]));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn uniform_logits_give_ln_c_loss() {
        let logits = Tensor::zeros(&[4, 10]);
        let (loss, _) = cross_entropy(&logits, &[0, 3, 5, 9]);
        assert!((loss - (10f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let logits = Tensor::from_vec(&[2, 3], vec![0.5, -0.2, 0.1, 1.0, 0.0, -1.0]);
        let labels = [2usize, 0];
        let (_, grad) = cross_entropy(&logits, &labels);
        let eps = 1e-3f32;
        for idx in 0..6 {
            let mut plus = logits.clone();
            plus.data[idx] += eps;
            let mut minus = logits.clone();
            minus.data[idx] -= eps;
            let (lp, _) = cross_entropy(&plus, &labels);
            let (lm, _) = cross_entropy(&minus, &labels);
            let fd = (lp - lm) / (2.0 * eps as f64);
            assert!(
                (fd - grad.data[idx] as f64).abs() < 1e-4,
                "idx={idx}: fd {fd} vs grad {}",
                grad.data[idx]
            );
        }
    }

    #[test]
    fn perfect_prediction_has_full_accuracy() {
        let logits = Tensor::from_vec(&[2, 3], vec![9.0, 0.0, 0.0, 0.0, 0.0, 9.0]);
        assert_eq!(accuracy(&logits, &[0, 2]), 1.0);
        assert_eq!(accuracy(&logits, &[1, 1]), 0.0);
    }
}
