//! Training metrics: per-step records, divergence detection, CSV/JSON
//! export — shared by the native trainer and the PJRT runtime trainer.

use crate::util::json::Json;

/// One training step's scalars.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f64,
    pub train_acc: f64,
}

/// A whole run's metrics.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub steps: Vec<StepRecord>,
    pub test_acc: Option<f64>,
    pub diverged: bool,
    /// Step at which divergence was first detected.
    pub diverged_at: Option<usize>,
    /// The run stopped early because its cooperative deadline passed
    /// (`TrainConfig::deadline`).
    pub deadline_exceeded: bool,
}

impl RunMetrics {
    pub fn push(&mut self, rec: StepRecord) {
        // Divergence: non-finite loss, or loss exploding far above the
        // chance-level ceiling after warmup.
        if !self.diverged && (!rec.loss.is_finite() || rec.loss > 50.0) {
            self.diverged = true;
            self.diverged_at = Some(rec.step);
        }
        self.steps.push(rec);
    }

    pub fn final_loss(&self) -> Option<f64> {
        self.steps.last().map(|r| r.loss)
    }

    /// Mean loss over the last `k` recorded steps (convergence plateau).
    pub fn tail_loss(&self, k: usize) -> Option<f64> {
        if self.steps.is_empty() {
            return None;
        }
        let tail = &self.steps[self.steps.len().saturating_sub(k)..];
        Some(tail.iter().map(|r| r.loss).sum::<f64>() / tail.len() as f64)
    }

    /// Mean training accuracy over the last `k` steps.
    pub fn tail_acc(&self, k: usize) -> Option<f64> {
        if self.steps.is_empty() {
            return None;
        }
        let tail = &self.steps[self.steps.len().saturating_sub(k)..];
        Some(tail.iter().map(|r| r.train_acc).sum::<f64>() / tail.len() as f64)
    }

    /// CSV export: `step,loss,train_acc`.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("step,loss,train_acc\n");
        for r in &self.steps {
            out.push_str(&format!("{},{},{}\n", r.step, r.loss, r.train_acc));
        }
        out
    }

    /// JSON export of the run summary plus the loss curve.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("diverged", self.diverged);
        if let Some(s) = self.diverged_at {
            j.set("diverged_at", s);
        }
        if self.deadline_exceeded {
            j.set("deadline_exceeded", true);
        }
        if let Some(a) = self.test_acc {
            j.set("test_acc", a);
        }
        j.set(
            "loss",
            Json::Arr(self.steps.iter().map(|r| Json::Num(r.loss)).collect()),
        );
        j.set(
            "steps",
            Json::Arr(
                self.steps
                    .iter()
                    .map(|r| Json::Num(r.step as f64))
                    .collect(),
            ),
        );
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, loss: f64) -> StepRecord {
        StepRecord {
            step,
            loss,
            train_acc: 0.5,
        }
    }

    #[test]
    fn detects_nan_divergence() {
        let mut m = RunMetrics::default();
        m.push(rec(0, 2.0));
        m.push(rec(1, f64::NAN));
        m.push(rec(2, 2.0));
        assert!(m.diverged);
        assert_eq!(m.diverged_at, Some(1));
    }

    #[test]
    fn detects_explosion() {
        let mut m = RunMetrics::default();
        m.push(rec(0, 2.0));
        m.push(rec(1, 1e6));
        assert!(m.diverged);
    }

    #[test]
    fn healthy_run_not_flagged() {
        let mut m = RunMetrics::default();
        for i in 0..100 {
            m.push(rec(i, 2.0 / (i + 1) as f64));
        }
        assert!(!m.diverged);
        assert!(m.tail_loss(10).unwrap() < 0.03);
    }

    #[test]
    fn csv_and_json_roundtrip() {
        let mut m = RunMetrics::default();
        m.push(rec(0, 2.5));
        m.push(rec(1, 1.5));
        m.test_acc = Some(0.9);
        let csv = m.to_csv();
        assert!(csv.starts_with("step,loss"));
        assert_eq!(csv.lines().count(), 3);
        let j = m.to_json();
        assert_eq!(j.get("test_acc").unwrap().as_f64(), Some(0.9));
        assert_eq!(j.get("loss").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn tail_handles_short_runs() {
        let mut m = RunMetrics::default();
        m.push(rec(0, 4.0));
        assert_eq!(m.tail_loss(10), Some(4.0));
        assert_eq!(RunMetrics::default().tail_loss(5), None);
    }
}
