//! Training substrate: a bit-accurate reduced-precision native trainer
//! (every GEMM routed through the softfloat simulator at its own
//! precision), the loss/optimizer pieces, and metrics with divergence
//! detection. The PJRT-artifact trainer lives in [`crate::runtime`]'s
//! exec layer and shares [`metrics`].

pub mod loss;
pub mod metrics;
pub mod native;
pub mod sgd;

pub use metrics::{RunMetrics, StepRecord};
pub use native::{NativeTrainer, PrecisionPlan, TrainConfig};
