//! Bit-accurate reduced-precision native trainer.
//!
//! A two-layer MLP classifier whose three GEMMs (FWD, BWD, GRAD — paper
//! Fig. 2) are each routed through the softfloat reduced-precision GEMM
//! at their *own* accumulation precision, exactly as the paper assigns
//! per-GEMM precisions in Table 1. Used by the Fig. 1a / Fig. 6 style
//! experiments where per-MAC rounding must be exact.

use std::cell::RefCell;

use crate::data::synth::Dataset;
use crate::softfloat::gemm::{
    rp_gemm_packed, GemmConfig, GemmCtx, Interrupted, Layout, QuantizedOperand,
};
use crate::softfloat::tensor::Tensor;
use crate::telemetry::trace;
use crate::trainer::loss::{accuracy, cross_entropy};
use crate::trainer::metrics::{RunMetrics, StepRecord};
use crate::trainer::sgd::{SgdConfig, SgdState};
use crate::util::rng::Pcg64;

/// Per-GEMM precision assignment (the unit Table 1 predicts).
#[derive(Clone, Copy, Debug)]
pub struct PrecisionPlan {
    pub fwd: GemmConfig,
    pub bwd: GemmConfig,
    pub grad: GemmConfig,
}

impl PrecisionPlan {
    /// Full-precision control arm (the paper's baseline: representation
    /// still (1,5,2) in their runs, but accumulation ideal; here we offer
    /// the pure-f64 arm for reference curves).
    pub fn baseline() -> PrecisionPlan {
        PrecisionPlan {
            fwd: GemmConfig::baseline(),
            bwd: GemmConfig::baseline(),
            grad: GemmConfig::baseline(),
        }
    }

    /// (1,5,2) representations with *ideal* accumulation — the fair
    /// baseline of the paper's Fig. 6 (representation effects excluded).
    pub fn fp8_ideal_acc() -> PrecisionPlan {
        let mut cfg = GemmConfig::paper(23, None);
        cfg.acc = crate::softfloat::FpFormat::new(11, 52);
        PrecisionPlan {
            fwd: cfg,
            bwd: cfg,
            grad: cfg,
        }
    }

    /// Uniform reduced accumulation width for all three GEMMs.
    pub fn uniform(m_acc: u32, chunk: Option<usize>) -> PrecisionPlan {
        let cfg = GemmConfig::paper(m_acc, chunk);
        PrecisionPlan {
            fwd: cfg,
            bwd: cfg,
            grad: cfg,
        }
    }

    /// Per-GEMM widths (the Table-1 shape).
    pub fn per_gemm(fwd: u32, bwd: u32, grad: u32, chunk: Option<usize>) -> PrecisionPlan {
        PrecisionPlan {
            fwd: GemmConfig::paper(fwd, chunk),
            bwd: GemmConfig::paper(bwd, chunk),
            grad: GemmConfig::paper(grad, chunk),
        }
    }
}

/// Trainer configuration.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub hidden: usize,
    pub steps: usize,
    pub batch: usize,
    pub sgd: SgdConfig,
    pub seed: u64,
    /// Record metrics every `log_every` steps (1 = every step).
    pub log_every: usize,
    /// Cooperative deadline: the step loop checks before each step and
    /// stops (flagging `RunMetrics::deadline_exceeded`) once passed.
    /// `None` (the default) never stops early.
    pub deadline: Option<std::time::Instant>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            hidden: 64,
            steps: 300,
            batch: 32,
            sgd: SgdConfig {
                lr: 0.05,
                momentum: 0.9,
                loss_scale: 1000.0,
            },
            seed: 42,
            log_every: 1,
            deadline: None,
        }
    }
}

/// Packed (representation-quantized) weight operands, one entry per
/// `(repr, mode)` key in use — the per-step operand cache: each weight
/// is quantized once per step however many GEMMs read it (W2 is read by
/// both FWD and BWD). Must be cleared whenever the weights change (the
/// SGD update at the end of [`NativeTrainer::step`]); a stale pack
/// would silently train on last step's weights.
#[derive(Default)]
struct WeightCache {
    w1: Vec<QuantizedOperand>,
    w2: Vec<QuantizedOperand>,
}

impl WeightCache {
    fn get<'a>(
        slot: &'a mut Vec<QuantizedOperand>,
        t: &Tensor,
        cfg: &GemmConfig,
    ) -> &'a QuantizedOperand {
        if let Some(i) = slot.iter().position(|q| q.matches(cfg)) {
            &slot[i]
        } else {
            slot.push(QuantizedOperand::for_cfg(t, cfg));
            slot.last().unwrap()
        }
    }

    fn clear(&mut self) {
        self.w1.clear();
        self.w2.clear();
    }
}

/// Two-layer MLP trained with reduced-precision GEMMs.
pub struct NativeTrainer {
    pub w1: Tensor, // [dim, hidden]
    pub w2: Tensor, // [hidden, classes]
    s1: SgdState,
    s2: SgdState,
    plan: PrecisionPlan,
    cfg: TrainConfig,
    cache: RefCell<WeightCache>,
}

impl NativeTrainer {
    pub fn new(dim: usize, classes: usize, plan: PrecisionPlan, cfg: TrainConfig) -> Self {
        let mut rng = Pcg64::seeded(cfg.seed);
        // He initialization: std = sqrt(2/fan_in) — the variance
        // engineering whose violation by swamping the paper studies (§3).
        let w1 = Tensor::randn(&[dim, cfg.hidden], (2.0 / dim as f64).sqrt(), &mut rng);
        let w2 = Tensor::randn(
            &[cfg.hidden, classes],
            (2.0 / cfg.hidden as f64).sqrt(),
            &mut rng,
        );
        NativeTrainer {
            s1: SgdState::new(&w1.shape),
            s2: SgdState::new(&w2.shape),
            w1,
            w2,
            plan,
            cfg,
            cache: RefCell::new(WeightCache::default()),
        }
    }

    /// Forward pass; returns (hidden-post-relu, logits).
    pub fn forward(&self, x: &Tensor) -> (Tensor, Tensor) {
        self.forward_ctx(x, &GemmCtx::default())
            .expect("forward: no deadline in the default context")
    }

    /// Forward pass under an execution context (threads + deadline);
    /// `Err` if the deadline fired inside one of the GEMMs.
    fn forward_ctx(&self, x: &Tensor, ctx: &GemmCtx) -> Result<(Tensor, Tensor), Interrupted> {
        let ctx = &GemmCtx { op: "fwd", ..*ctx };
        let fwd = &self.plan.fwd;
        let xq = QuantizedOperand::for_cfg(x, fwd);
        let h_pre = rp_gemm_packed(
            &xq,
            WeightCache::get(&mut self.cache.borrow_mut().w1, &self.w1, fwd),
            fwd,
            Layout::NN,
            ctx,
        )?;
        let h = h_pre.map(|v| v.max(0.0));
        let hq = QuantizedOperand::for_cfg(&h, fwd);
        let logits = rp_gemm_packed(
            &hq,
            WeightCache::get(&mut self.cache.borrow_mut().w2, &self.w2, fwd),
            fwd,
            Layout::NN,
            ctx,
        )?;
        Ok((h, logits))
    }

    /// One SGD step on batch `(x, y)`; returns (loss, train-acc), or
    /// [`Interrupted`] if the configured deadline fired inside a GEMM —
    /// in which case the weights are untouched (no partial update).
    pub fn step(&mut self, x: &Tensor, y: &[usize]) -> Result<(f64, f64), Interrupted> {
        let ctx = GemmCtx {
            threads: 0,
            deadline: self.cfg.deadline,
            ..GemmCtx::default()
        };
        let (h, logits) = self.forward_ctx(x, &ctx)?;
        let grad_ctx = GemmCtx { op: "grad", ..ctx };
        let bwd_ctx = GemmCtx { op: "bwd", ..ctx };
        let (loss, mut dlogits) = cross_entropy(&logits, y);
        let acc = accuracy(&logits, y);

        // Loss scaling before anything touches (1,5,2) quantization.
        let scale = self.cfg.sgd.loss_scale as f32;
        for g in dlogits.data.iter_mut() {
            *g *= scale;
        }

        let (bwd, grad) = (&self.plan.bwd, &self.plan.grad);
        // Pack this step's activations once; dlogits feeds both GRAD and
        // BWD from the same pack when their (repr, mode) keys agree.
        let dl_grad = QuantizedOperand::for_cfg(&dlogits, grad);
        let dl_bwd_store;
        let dl_bwd = if dl_grad.matches(bwd) {
            &dl_grad
        } else {
            dl_bwd_store = QuantizedOperand::for_cfg(&dlogits, bwd);
            &dl_bwd_store
        };
        let hq = QuantizedOperand::for_cfg(&h, grad);
        let xq = QuantizedOperand::for_cfg(x, grad);

        // GRAD GEMM: dW2 = hᵀ · dlogits (accumulation over the batch) —
        // the TN layout reads h transposed without materializing `h.t()`.
        let dw2 = rp_gemm_packed(&hq, &dl_grad, grad, Layout::TN, &grad_ctx)?;
        // BWD GEMM: dh = dlogits · W2ᵀ (accumulation over classes) — NT
        // reuses the same packed W2 the forward pass quantized.
        let mut dh = rp_gemm_packed(
            dl_bwd,
            WeightCache::get(&mut self.cache.borrow_mut().w2, &self.w2, bwd),
            bwd,
            Layout::NT,
            &bwd_ctx,
        )?;
        // ReLU backward mask — this is what makes BWD/GRAD operands
        // sparse (NZR ≈ 0.5), as §4.3 models.
        for (g, hv) in dh.data.iter_mut().zip(&h.data) {
            if *hv <= 0.0 {
                *g = 0.0;
            }
        }
        // GRAD GEMM: dW1 = xᵀ · dh.
        let dhq = QuantizedOperand::for_cfg(&dh, grad);
        let dw1 = rp_gemm_packed(&xq, &dhq, grad, Layout::TN, &grad_ctx)?;

        // Apply updates only after every GEMM succeeded, then drop the
        // packed weights: they describe the pre-update values.
        self.s2.step(&mut self.w2, &dw2, &self.cfg.sgd);
        self.s1.step(&mut self.w1, &dw1, &self.cfg.sgd);
        self.cache.borrow_mut().clear();
        Ok((loss, acc))
    }

    /// Full training loop over a dataset; returns the metrics trace.
    /// Stops early on divergence (loss NaN/∞ or explosion).
    pub fn train(&mut self, data: &Dataset) -> RunMetrics {
        // Resolve the step metrics once per run, not per step.
        let tel = crate::telemetry::enabled().then(|| {
            (
                crate::telemetry::counter("abws_train_steps_total"),
                crate::telemetry::histogram("abws_train_step_ns"),
            )
        });
        let mut metrics = RunMetrics::default();
        for step in 0..self.cfg.steps {
            if let Some(d) = self.cfg.deadline {
                if std::time::Instant::now() >= d {
                    metrics.deadline_exceeded = true;
                    break;
                }
            }
            let (xb, yb) = data.batch(step, self.cfg.batch);
            let timer = tel.as_ref().map(|_| crate::telemetry::Timer::start());
            let _tspan = if trace::enabled() {
                trace::TraceSpan::enter("train.step").attr("step", step.to_string())
            } else {
                trace::TraceSpan::noop()
            };
            let (loss, acc) = match self.step(&xb, &yb) {
                Ok(v) => v,
                // The deadline fired between row panels inside a GEMM:
                // same cooperative stop as the pre-step check, just with
                // finer granularity.
                Err(Interrupted) => {
                    metrics.deadline_exceeded = true;
                    break;
                }
            };
            if let (Some((steps, step_ns)), Some(timer)) = (&tel, timer) {
                steps.inc();
                step_ns.record(timer.elapsed_ns());
            }
            if step % self.cfg.log_every == 0 {
                metrics.push(StepRecord {
                    step,
                    loss,
                    train_acc: acc,
                });
            }
            if metrics.diverged {
                break;
            }
        }
        metrics
    }

    /// Evaluate top-1 accuracy on a dataset (batched).
    pub fn evaluate(&self, data: &Dataset) -> f64 {
        let bs = self.cfg.batch;
        let batches = data.len().div_ceil(bs).max(1);
        let mut acc_sum = 0.0;
        for b in 0..batches {
            let (xb, yb) = data.batch(b, bs);
            let (_, logits) = self.forward(&xb);
            acc_sum += accuracy(&logits, &yb);
        }
        acc_sum / batches as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};

    fn small_data() -> (Dataset, Dataset) {
        generate(&SynthSpec {
            n_train: 256,
            n_test: 128,
            dim: 32,
            classes: 4,
            noise: 1.6, // hard enough that precision damage shows
            seed: 5,
        })
    }

    #[test]
    fn baseline_learns_the_task() {
        let (train, test) = small_data();
        let cfg = TrainConfig {
            steps: 150,
            hidden: 32,
            ..Default::default()
        };
        let mut t = NativeTrainer::new(32, 4, PrecisionPlan::baseline(), cfg);
        let m = t.train(&train);
        assert!(!m.diverged);
        let first = m.steps.first().unwrap().loss;
        let last = m.tail_loss(20).unwrap();
        assert!(last < 0.6 * first, "loss {first} → {last}");
        let acc = t.evaluate(&test);
        assert!(acc > 0.7, "test acc {acc}");
    }

    #[test]
    fn adequate_reduced_precision_tracks_baseline() {
        let (train, test) = small_data();
        let cfg = TrainConfig {
            steps: 150,
            hidden: 32,
            ..Default::default()
        };
        // Short accumulations (n ≤ 32) need few bits; 12 is generous.
        let mut t = NativeTrainer::new(32, 4, PrecisionPlan::uniform(12, None), cfg);
        let m = t.train(&train);
        assert!(!m.diverged);
        let acc = t.evaluate(&test);
        let mut tb = NativeTrainer::new(32, 4, PrecisionPlan::baseline(), cfg);
        tb.train(&train);
        let acc_base = tb.evaluate(&test);
        assert!(
            acc >= acc_base - 0.08,
            "reduced {acc} vs baseline {acc_base}"
        );
    }

    #[test]
    fn starved_accumulator_degrades() {
        let (train, test) = small_data();
        let cfg = TrainConfig {
            steps: 150,
            hidden: 32,
            ..Default::default()
        };
        let mut t = NativeTrainer::new(32, 4, PrecisionPlan::uniform(1, None), cfg);
        let m = t.train(&train);
        let acc = t.evaluate(&test);
        let mut tb = NativeTrainer::new(32, 4, PrecisionPlan::baseline(), cfg);
        let mb = tb.train(&train);
        let acc_base = tb.evaluate(&test);
        // A one-bit accumulator must hurt: divergence, an accuracy gap, or
        // a clearly worse converged loss plateau.
        let loss_gap =
            m.tail_loss(20).unwrap_or(f64::INFINITY) > 1.5 * mb.tail_loss(20).unwrap();
        assert!(
            m.diverged || loss_gap || acc < acc_base - 0.05,
            "m_acc=1 should hurt: acc {acc} vs {acc_base}, tail loss {:?} vs {:?}",
            m.tail_loss(20),
            mb.tail_loss(20)
        );
    }

    #[test]
    fn expired_deadline_stops_before_the_first_step() {
        let (train, _) = small_data();
        let cfg = TrainConfig {
            steps: 50,
            hidden: 16,
            deadline: Some(std::time::Instant::now() - std::time::Duration::from_millis(1)),
            ..Default::default()
        };
        let mut t = NativeTrainer::new(32, 4, PrecisionPlan::baseline(), cfg);
        let m = t.train(&train);
        assert!(m.deadline_exceeded);
        assert!(m.steps.is_empty());
        assert!(m.to_json().get("deadline_exceeded").unwrap().as_bool().unwrap());
    }

    #[test]
    fn forward_shapes() {
        let (train, _) = small_data();
        let cfg = TrainConfig {
            hidden: 16,
            ..Default::default()
        };
        let t = NativeTrainer::new(32, 4, PrecisionPlan::baseline(), cfg);
        let (xb, _) = train.batch(0, 8);
        let (h, logits) = t.forward(&xb);
        assert_eq!(h.shape, vec![8, 16]);
        assert_eq!(logits.shape, vec![8, 4]);
    }

    fn bits(t: &Tensor) -> Vec<u32> {
        t.data.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn forward_matches_reference_gemms() {
        // The packed, layout-aware, parallel forward must reproduce the
        // scalar-reference composition bit-for-bit.
        use crate::softfloat::gemm::rp_gemm_ref;
        let (train, _) = small_data();
        let cfg = TrainConfig {
            hidden: 16,
            ..Default::default()
        };
        let t = NativeTrainer::new(32, 4, PrecisionPlan::uniform(8, Some(16)), cfg);
        let (xb, _) = train.batch(0, 8);
        let fwd = &t.plan.fwd;
        let h_pre = rp_gemm_ref(&xb, &t.w1, fwd);
        let h_want = h_pre.map(|v| v.max(0.0));
        let logits_want = rp_gemm_ref(&h_want, &t.w2, fwd);
        let (h, logits) = t.forward(&xb);
        assert_eq!(bits(&h), bits(&h_want));
        assert_eq!(bits(&logits), bits(&logits_want));
    }

    #[test]
    fn weight_cache_invalidated_by_step() {
        // Trainer A warms its packed-weight cache with a forward pass
        // before stepping; trainer B steps cold. If the SGD update failed
        // to drop A's pack, A's post-step forward would run on stale
        // weights and diverge from B's.
        let (train, _) = small_data();
        let cfg = TrainConfig {
            steps: 5,
            hidden: 16,
            ..Default::default()
        };
        let mut a = NativeTrainer::new(32, 4, PrecisionPlan::uniform(10, Some(8)), cfg);
        let mut b = NativeTrainer::new(32, 4, PrecisionPlan::uniform(10, Some(8)), cfg);
        let (xb, yb) = train.batch(0, 8);
        let _ = a.forward(&xb);
        a.step(&xb, &yb).unwrap();
        b.step(&xb, &yb).unwrap();
        let (_, la) = a.forward(&xb);
        let (_, lb) = b.forward(&xb);
        assert_eq!(bits(&la), bits(&lb));
    }

    #[test]
    fn expired_deadline_interrupts_a_step_mid_gemm() {
        let (train, _) = small_data();
        let cfg = TrainConfig {
            hidden: 16,
            deadline: Some(std::time::Instant::now() - std::time::Duration::from_millis(1)),
            ..Default::default()
        };
        let mut t = NativeTrainer::new(32, 4, PrecisionPlan::baseline(), cfg);
        let (xb, yb) = train.batch(0, 8);
        let w1_before = t.w1.data.clone();
        let w2_before = t.w2.data.clone();
        assert!(t.step(&xb, &yb).is_err());
        // No partial update escaped the interrupted step.
        assert_eq!(t.w1.data, w1_before);
        assert_eq!(t.w2.data, w2_before);
    }
}
