//! Plain SGD with momentum plus the paper's loss-scaling technique
//! (Micikevicius et al. 2017): gradients are computed on `scale × loss`
//! to keep small activation gradients above the (1,5,2) underflow floor,
//! then un-scaled at the weight update.

use crate::softfloat::tensor::Tensor;

/// SGD-with-momentum state for one parameter tensor.
#[derive(Clone, Debug)]
pub struct SgdState {
    pub velocity: Tensor,
}

/// SGD hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct SgdConfig {
    pub lr: f64,
    pub momentum: f64,
    /// Loss scale (paper §5 uses a single factor of 1000 for all models).
    pub loss_scale: f64,
}

impl Default for SgdConfig {
    fn default() -> Self {
        SgdConfig {
            lr: 0.1,
            momentum: 0.9,
            loss_scale: 1000.0,
        }
    }
}

impl SgdState {
    pub fn new(shape: &[usize]) -> SgdState {
        SgdState {
            velocity: Tensor::zeros(shape),
        }
    }

    /// One update step: `v ← μ·v + g/scale`, `w ← w − lr·v`.
    ///
    /// `grad` is the *scaled* gradient (computed from `scale × loss`);
    /// the division here is the master-weight unscaling step.
    pub fn step(&mut self, w: &mut Tensor, grad: &Tensor, cfg: &SgdConfig) {
        assert_eq!(w.shape, grad.shape);
        let inv = 1.0 / cfg.loss_scale;
        for i in 0..w.data.len() {
            let g = grad.data[i] as f64 * inv;
            let v = cfg.momentum * self.velocity.data[i] as f64 + g;
            self.velocity.data[i] = v as f32;
            w.data[i] = (w.data[i] as f64 - cfg.lr * v) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descends_a_quadratic() {
        // Minimize f(w) = ½‖w‖²; grad = w. SGD must shrink the norm.
        let mut w = Tensor::from_vec(&[3], vec![1.0, -2.0, 3.0]);
        let mut st = SgdState::new(&[3]);
        let cfg = SgdConfig {
            lr: 0.1,
            momentum: 0.0,
            loss_scale: 1.0,
        };
        for _ in 0..100 {
            let grad = w.clone();
            st.step(&mut w, &grad, &cfg);
        }
        let norm: f32 = w.data.iter().map(|x| x * x).sum();
        assert!(norm < 1e-6, "norm={norm}");
    }

    #[test]
    fn loss_scaling_cancels_exactly_without_momentum() {
        let cfg_scaled = SgdConfig {
            lr: 0.1,
            momentum: 0.0,
            loss_scale: 1000.0,
        };
        let cfg_plain = SgdConfig {
            lr: 0.1,
            momentum: 0.0,
            loss_scale: 1.0,
        };
        let grad = Tensor::from_vec(&[2], vec![0.5, -0.25]);
        let scaled_grad = grad.map(|g| g * 1000.0);
        let mut w1 = Tensor::from_vec(&[2], vec![1.0, 1.0]);
        let mut w2 = w1.clone();
        SgdState::new(&[2]).step(&mut w1, &scaled_grad, &cfg_scaled);
        SgdState::new(&[2]).step(&mut w2, &grad, &cfg_plain);
        for (a, b) in w1.data.iter().zip(&w2.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn momentum_accumulates() {
        let cfg = SgdConfig {
            lr: 1.0,
            momentum: 0.5,
            loss_scale: 1.0,
        };
        let mut w = Tensor::from_vec(&[1], vec![0.0]);
        let mut st = SgdState::new(&[1]);
        let grad = Tensor::from_vec(&[1], vec![1.0]);
        st.step(&mut w, &grad, &cfg); // v=1, w=-1
        st.step(&mut w, &grad, &cfg); // v=1.5, w=-2.5
        assert!((w.data[0] + 2.5).abs() < 1e-6, "w={}", w.data[0]);
    }
}
