//! Tiny command-line argument parser (no `clap` in the offline build).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed getters. Options may repeat: [`Args::get`]
//! returns the last value (flag-override semantics), [`Args::get_all`]
//! returns every value in argv order (repeatable options like the
//! precision advisor's `--conv`/`--fc` layer lists).

/// Parsed arguments: positionals in order plus `--key` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    /// `(key, value)` pairs in argv order — repeats preserved.
    options: Vec<(String, String)>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut args = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.push((k.to_string(), v.to_string()));
                } else if iter
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.push((rest.to_string(), v));
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process environment (skips argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Last value given for `name` (later occurrences override earlier).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options
            .iter()
            .rev()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Every value given for `name`, in argv order. Empty if absent.
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.options
            .iter()
            .filter(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    /// All `(key, value)` options in argv order — for callers that
    /// interleave several repeatable options and need the global order
    /// (e.g. `--conv a --fc b --conv c` as three layers in sequence).
    pub fn entries(&self) -> impl Iterator<Item = (&str, &str)> {
        self.options.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|s| {
                s.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{s}'"))
            })
            .unwrap_or(default)
    }

    pub fn get_u32(&self, name: &str, default: u32) -> u32 {
        self.get_usize(name, default as usize) as u32
    }

    pub fn get_i64(&self, name: &str, default: i64) -> i64 {
        self.get(name)
            .map(|s| {
                s.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{s}'"))
            })
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|s| {
                s.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects a number, got '{s}'"))
            })
            .unwrap_or(default)
    }

    /// Comma-separated list of integers, e.g. `--maccs 8,10,12`.
    pub fn get_u32_list(&self, name: &str, default: &[u32]) -> Vec<u32> {
        match self.get(name) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter(|t| !t.is_empty())
                .map(|t| {
                    t.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{name}: bad integer '{t}'"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["predict", "--net", "resnet18", "--chunk=64", "--verbose"]);
        assert_eq!(a.positional, vec!["predict"]);
        assert_eq!(a.get("net"), Some("resnet18"));
        assert_eq!(a.get_usize("chunk", 1), 64);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--dry-run", "--force"]);
        assert!(a.flag("dry-run") && a.flag("force"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_f64("sigma", 1.5), 1.5);
        assert_eq!(a.get_or("out", "results.json"), "results.json");
    }

    #[test]
    fn int_lists() {
        let a = parse(&["--maccs", "8,10,12"]);
        assert_eq!(a.get_u32_list("maccs", &[]), vec![8, 10, 12]);
        assert_eq!(a.get_u32_list("other", &[5]), vec![5]);
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse(&["--pp", "-2"]);
        assert_eq!(a.get_i64("pp", 0), -2);
    }

    #[test]
    fn repeated_options_last_wins_for_get() {
        let a = parse(&["--chunk", "32", "--chunk", "64"]);
        assert_eq!(a.get("chunk"), Some("64"));
        assert_eq!(a.get_usize("chunk", 0), 64);
    }

    #[test]
    fn get_all_preserves_order_and_repeats() {
        let a = parse(&[
            "--conv", "3x64x7x112", "--fc", "4096x1000", "--conv", "64x128x3x56",
        ]);
        assert_eq!(a.get_all("conv"), vec!["3x64x7x112", "64x128x3x56"]);
        assert_eq!(a.get_all("fc"), vec!["4096x1000"]);
        assert!(a.get_all("pool").is_empty());
        // entries() keeps the *cross-key* argv order.
        let keys: Vec<&str> = a.entries().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["conv", "fc", "conv"]);
    }
}
