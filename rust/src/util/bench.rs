//! Micro-benchmark harness (no `criterion` offline): warmup + timed
//! iterations with median/mean/stddev reporting, black-box value sink and
//! a tabular reporter shared by all `cargo bench` targets.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub stddev: Duration,
    pub min: Duration,
}

impl Measurement {
    pub fn report(&self) {
        println!(
            "{:<48} {:>12} {:>12} {:>12} {:>8}",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.stddev),
            self.iters
        );
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Print the report header once per bench binary.
pub fn header() {
    println!(
        "{:<48} {:>12} {:>12} {:>12} {:>8}",
        "benchmark", "median", "mean", "stddev", "iters"
    );
    println!("{}", "-".repeat(96));
}

/// Time `f`, auto-calibrating iteration count to fill ~`budget` after a
/// warmup. Returns and prints the measurement.
pub fn bench<F, R>(name: &str, budget: Duration, mut f: F) -> Measurement
where
    F: FnMut() -> R,
{
    // Warmup & calibration: find iters so one sample ≈ budget/20.
    let t0 = Instant::now();
    black_box(f());
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let samples: usize = 20;
    let per_sample = budget / samples as u32;
    let iters = (per_sample.as_nanos() / once.as_nanos()).clamp(1, 1_000_000) as u64;

    let mut times: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters {
            black_box(f());
        }
        times.push(t.elapsed().as_nanos() as f64 / iters as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / times.len() as f64;
    let m = Measurement {
        name: name.to_string(),
        iters,
        mean: Duration::from_nanos(mean as u64),
        median: Duration::from_nanos(median as u64),
        stddev: Duration::from_nanos(var.sqrt() as u64),
        min: Duration::from_nanos(times[0] as u64),
    };
    m.report();
    m
}

/// Convenience: default 0.5 s budget.
pub fn quick<F, R>(name: &str, f: F) -> Measurement
where
    F: FnMut() -> R,
{
    bench(name, Duration::from_millis(500), f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        // black_box the loop bound so release builds can't fold the sum
        // to a constant (which would measure as 0 ns).
        let m = bench("noop-ish", Duration::from_millis(20), || {
            (0..black_box(1000u64)).map(black_box).sum::<u64>()
        });
        assert!(m.median > Duration::ZERO);
        assert!(m.iters >= 1);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_dur(Duration::from_nanos(12)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(12)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(12)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}
