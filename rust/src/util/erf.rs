//! Complementary error function and the Gaussian Q-function.
//!
//! The VRR formulas (paper Eqs. 1–2) are built from
//! `Q(x) = P[N(0,1) > x] = erfc(x/√2)/2`. The knees of the VRR curves live
//! at arguments `2^{m_acc}/√n ∈ [0.5, 8]`, so we need good *relative*
//! accuracy across the whole positive axis, including deep tails (the
//! normalization constant `k` in Lemma 1 sums thousands of tiny `q_i`).
//!
//! Implementation: the rational Chebyshev approximation of W. J. Cody as
//! popularised by Numerical Recipes (`erfc(x) = t·exp(-x² + P(t))`,
//! `t = 1/(1+x/2)`), which has |relative error| ≤ 1.2e-7 everywhere. That
//! is 5+ orders of magnitude tighter than anything the statistical model
//! itself claims.

/// Complementary error function, `erfc(x) = 2/√π ∫_x^∞ e^{-t²} dt`.
///
/// Valid for all finite `x`; relative error ≤ 1.2e-7.
pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    // Horner form of the NR/Cody polynomial in t.
    let poly = -z * z - 1.265_512_23
        + t * (1.000_023_68
            + t * (0.374_091_96
                + t * (0.096_784_18
                    + t * (-0.186_288_06
                        + t * (0.278_868_07
                            + t * (-1.135_203_98
                                + t * (1.488_515_87
                                    + t * (-0.822_152_23 + t * 0.170_872_77))))))));
    let ans = t * poly.exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Error function, `erf(x) = 1 - erfc(x)`.
pub fn erf(x: f64) -> f64 {
    1.0 - erfc(x)
}

/// The Gaussian tail probability `Q(x) = P[N(0,1) > x] = erfc(x/√2)/2`.
#[inline]
pub fn q_function(x: f64) -> f64 {
    0.5 * erfc(x * std::f64::consts::FRAC_1_SQRT_2)
}

/// `2·Q(x)` — the two-sided tail `P[|N(0,1)| > x]`, the building block of
/// every probability in the VRR analysis.
#[inline]
pub fn two_q(x: f64) -> f64 {
    erfc(x * std::f64::consts::FRAC_1_SQRT_2)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// High-accuracy reference values (computed with mpmath, 50 digits).
    const ERFC_REF: &[(f64, f64)] = &[
        (0.0, 1.0),
        (0.1, 0.887537083981715),
        (0.5, 0.479500122186953),
        (1.0, 0.157299207050285),
        (1.5, 0.0338948535246893),
        (2.0, 0.00467773498104727),
        (3.0, 2.20904969985854e-5),
        (4.0, 1.54172579002800e-8),
        (5.0, 1.53745979442803e-12),
        (6.0, 2.15197367124989e-17),
        (8.0, 1.12242971729829e-29),
    ];

    #[test]
    fn erfc_matches_reference() {
        for &(x, want) in ERFC_REF {
            let got = erfc(x);
            let rel = if want == 0.0 {
                got.abs()
            } else {
                ((got - want) / want).abs()
            };
            assert!(rel < 2e-7, "erfc({x}) = {got}, want {want}, rel {rel}");
        }
    }

    #[test]
    fn erfc_negative_axis() {
        for &(x, want) in ERFC_REF {
            let got = erfc(-x);
            let want_neg = 2.0 - want;
            assert!(
                ((got - want_neg) / want_neg).abs() < 2e-7,
                "erfc({}) = {got}",
                -x
            );
        }
    }

    #[test]
    fn q_function_basics() {
        // Q(0) = 1/2 (within the approximation's 1.2e-7 relative error);
        // Q is decreasing; symmetric: Q(-x) = 1 - Q(x).
        assert!((q_function(0.0) - 0.5).abs() < 1e-7);
        let mut prev = q_function(0.0);
        for i in 1..100 {
            let q = q_function(i as f64 * 0.1);
            assert!(q < prev);
            prev = q;
        }
        for x in [0.3, 1.0, 2.5] {
            assert!((q_function(-x) - (1.0 - q_function(x))).abs() < 1e-7);
        }
    }

    #[test]
    fn q_function_reference_values() {
        // Q(1.96) ≈ 0.0249979; Q(1) ≈ 0.158655; Q(3) ≈ 0.00134990.
        assert!((q_function(1.96) - 0.024997895).abs() < 1e-7);
        assert!((q_function(1.0) - 0.1586552539).abs() < 1e-7);
        assert!((q_function(3.0) - 0.0013498980).abs() < 1e-8);
    }

    #[test]
    fn erf_erfc_complementarity() {
        for i in 0..80 {
            let x = -4.0 + i as f64 * 0.1;
            assert!((erf(x) + erfc(x) - 1.0).abs() < 1e-12);
        }
    }
}
