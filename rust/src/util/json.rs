//! Minimal JSON value, serializer and parser.
//!
//! The experiment coordinator writes metrics/results as JSON (and the
//! Python side writes golden VRR values as JSON for cross-language
//! checks). The offline build has no `serde`, so this is a small,
//! well-tested implementation covering the full JSON grammar we use:
//! objects, arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so serialization is
/// deterministic (stable key order) — important for golden files.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics if not an object).
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}
impl From<Vec<f64>> for Json {
    fn from(v: Vec<f64>) -> Json {
        Json::Arr(v.into_iter().map(Json::Num).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_num(x: f64, out: &mut String) {
    if x.is_nan() {
        out.push_str("null"); // JSON has no NaN; null is the conventional stand-in
    } else if x.is_infinite() {
        out.push_str(if x > 0.0 { "1e999" } else { "-1e999" });
    } else if x == x.trunc() && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", "vrr").set("n", 4096.0).set("ok", true);
        j.set("arr", vec![1.0, 2.5, -3.0]);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": null}, "x\ny"], "c": -1.5e3}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_f64(), Some(-1500.0));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].get("b"), Some(&Json::Null));
        assert_eq!(arr[2].as_str(), Some("x\ny"));
    }

    #[test]
    fn parse_errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = "quote\" back\\slash \n tab\t unicode\u{263a}";
        let j = Json::Str(s.to_string());
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.as_str(), Some(s));
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(-0.5).to_string(), "-0.5");
    }

    #[test]
    fn nan_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }
}
