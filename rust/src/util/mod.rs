//! Substrate utilities built from scratch for the offline environment:
//! a PCG64 RNG with Gaussian sampling, a high-accuracy `erfc`, descriptive
//! statistics, a minimal JSON value + writer/parser (metrics interchange),
//! a tiny argv parser for the CLI, and a micro-benchmark harness used by
//! the `cargo bench` targets.

pub mod argparse;
pub mod bench;
pub mod erf;
pub mod json;
pub mod rng;
pub mod stats;

pub use erf::{erfc, q_function};
pub use rng::Pcg64;
