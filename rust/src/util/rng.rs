//! PCG64 (XSL-RR 128/64) pseudo-random number generator plus Gaussian and
//! Bernoulli sampling. Implemented from scratch: the offline build has no
//! `rand` crate, and the Monte-Carlo experiments need a fast, seedable,
//! reproducible stream.

/// PCG XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
///
/// Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
/// Statistically Good Algorithms for Random Number Generation" (2014).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and stream id. Different `stream`
    /// values yield statistically independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    /// Seed-only constructor on the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xa02b_dbf7_bb3c_0a7)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53 bits of entropy.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift with
    /// rejection (unbiased).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            if (m as u64) >= threshold {
                return (m >> 64) as u64;
            }
        }
    }

    /// Standard normal via Box–Muller (uses both outputs; caches one).
    pub fn normal(&mut self) -> f64 {
        // Polar Box–Muller: rejection keeps tails exact and avoids trig.
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal with the given mean / standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli trial with probability `p` of `true`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fill a slice with iid standard-normal samples scaled by `std`.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f64) {
        for x in out.iter_mut() {
            *x = (self.normal() * std) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Pcg64::seeded(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn next_below_unbiased_small() {
        let mut rng = Pcg64::seeded(3);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[rng.next_below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
