//! Descriptive statistics used throughout the Monte-Carlo validation and
//! the experiment harness (means, variances, quantiles, Welford online
//! accumulation, and simple linear regression for trend checks).

/// Online mean/variance accumulator (Welford's algorithm) — numerically
/// stable even for millions of samples with large dynamic range, which is
/// exactly the regime of swamped partial sums.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (divides by n). The VRR compares second moments
    /// of zero-mean ensembles, so the population convention is the right
    /// one (`Var(s_n) = E[s_n²]`).
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Unbiased sample variance (divides by n-1).
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Merge two accumulators (parallel reduction).
    pub fn merge(&self, other: &Welford) -> Welford {
        if self.n == 0 {
            return other.clone();
        }
        if other.n == 0 {
            return self.clone();
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * other.n as f64 / n as f64;
        let m2 =
            self.m2 + other.m2 + delta * delta * self.n as f64 * other.n as f64 / n as f64;
        Welford { n, mean, m2 }
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance of a slice.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Second raw moment `E[x²]` — the quantity the VRR actually retains
/// (zero-mean ensembles: Var = E[x²]).
pub fn second_moment(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().map(|x| x * x).sum::<f64>() / xs.len() as f64
}

/// Linear-interpolated quantile, `q ∈ [0,1]`, on a *sorted copy*.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Ordinary least squares fit `y = a + b·x`; returns `(a, b)`.
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
    }
    if sxx == 0.0 || n < 2.0 {
        return (my, 0.0);
    }
    let b = sxy / sxx;
    (my - b * mx, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 3.0 + 1.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-10);
        assert!((w.variance() - variance(&xs)).abs() < 1e-10);
    }

    #[test]
    fn welford_merge_matches_single_pass() {
        let xs: Vec<f64> = (0..500).map(|i| (i as f64 * 0.37).cos()).collect();
        let (a, b) = xs.split_at(123);
        let mut wa = Welford::new();
        let mut wb = Welford::new();
        a.iter().for_each(|&x| wa.push(x));
        b.iter().for_each(|&x| wb.push(x));
        let merged = wa.merge(&wb);
        let mut whole = Welford::new();
        xs.iter().for_each(|&x| whole.push(x));
        assert!((merged.mean() - whole.mean()).abs() < 1e-12);
        assert!((merged.variance() - whole.variance()).abs() < 1e-12);
        assert_eq!(merged.count(), whole.count());
    }

    #[test]
    fn quantile_endpoints_and_median() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
    }

    #[test]
    fn linfit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.5 * x - 7.0).collect();
        let (a, b) = linfit(&xs, &ys);
        assert!((a + 7.0).abs() < 1e-9);
        assert!((b - 2.5).abs() < 1e-9);
    }

    #[test]
    fn second_moment_zero_mean() {
        let xs = [1.0, -1.0, 2.0, -2.0];
        assert!((second_moment(&xs) - 2.5).abs() < 1e-12);
    }
}
