//! Corollary 1 — VRR of a two-level **chunked** accumulation (Eq. 3):
//!
//! ```text
//! VRR_chunk = VRR(m_acc, m_p, n₁) · VRR(m_acc, min(m_acc, m_p + log₂ n₁), n₂)
//! ```
//!
//! `n₁` is the chunk size, `n₂ = n/n₁` the number of chunks; the
//! inter-chunk inputs carry `m_p + log₂ n₁` mantissa bits (logarithmic
//! mantissa growth of a sum of statistically similar terms), capped at
//! the accumulator width.

use super::theorem::vrr;

/// Effective mantissa width of intra-chunk results entering the
/// inter-chunk accumulation: `min(m_acc, m_p + log₂ n₁)` (rounded to the
/// nearest integer bit for non-power-of-two chunk sizes).
pub fn interchunk_m_p(m_acc: u32, m_p: u32, n1: usize) -> u32 {
    let growth = (n1.max(1) as f64).log2().round() as u32;
    (m_p + growth).min(m_acc)
}

/// Corollary 1 (Eq. 3): VRR of an `n = n₁ × n₂` chunked accumulation.
pub fn vrr_chunked(m_acc: u32, m_p: u32, n1: usize, n2: usize) -> f64 {
    vrr(m_acc, m_p, n1) * vrr(m_acc, interchunk_m_p(m_acc, m_p, n1), n2)
}

/// Convenience: chunked VRR for a total length `n` and chunk size
/// `chunk`, with the ragged final chunk folded in by rounding the chunk
/// count up (`n₂ = ⌈n/chunk⌉`) — the conservative choice.
pub fn vrr_chunked_total(m_acc: u32, m_p: u32, n: usize, chunk: usize) -> f64 {
    assert!(chunk > 0);
    if n <= chunk {
        // Degenerates to a single plain accumulation.
        return vrr(m_acc, m_p, n);
    }
    let n2 = n.div_ceil(chunk);
    vrr_chunked(m_acc, m_p, chunk, n2)
}

#[cfg(test)]
mod tests {
    use super::*;

    const MP: u32 = 5;

    #[test]
    fn chunking_beats_plain_past_the_knee() {
        // The paper's headline chunking claim (Fig. 5b vs 5a): for the same
        // m_acc, chunk-64 accumulation retains far more variance.
        for m_acc in [6, 8, 10] {
            let n = 1usize << (2 * m_acc); // past the plain knee
            let plain = vrr(m_acc, MP, n);
            let chunked = vrr_chunked_total(m_acc, MP, n, 64);
            assert!(
                chunked > plain,
                "m={m_acc} n={n}: chunked {chunked} ≤ plain {plain}"
            );
        }
    }

    #[test]
    fn flat_maximum_over_chunk_size() {
        // Fig. 5c: VRR vs chunk size has a wide flat top — neighbouring
        // chunk sizes in the moderate regime differ by < 1%.
        let (m_acc, n) = (8, 1usize << 16);
        let mid: Vec<f64> = [32usize, 64, 128, 256]
            .iter()
            .map(|&c| vrr_chunked_total(m_acc, MP, n, c))
            .collect();
        for w in mid.windows(2) {
            assert!((w[0] - w[1]).abs() < 0.01, "{mid:?}");
        }
        // While extreme chunk sizes (1 or n) collapse toward the plain VRR.
        let tiny = vrr_chunked_total(m_acc, MP, n, 1);
        let huge = vrr_chunked_total(m_acc, MP, n, n);
        let plain = vrr(m_acc, MP, n);
        assert!(tiny <= mid[0] + 1e-9);
        assert!((huge - plain).abs() < 1e-12);
    }

    #[test]
    fn interchunk_precision_growth() {
        assert_eq!(interchunk_m_p(12, 5, 64), 11); // 5 + 6
        assert_eq!(interchunk_m_p(9, 5, 64), 9); // capped at m_acc
        assert_eq!(interchunk_m_p(12, 5, 1), 5); // no growth
    }

    #[test]
    fn single_chunk_degenerates_to_plain() {
        assert_eq!(
            vrr_chunked_total(8, MP, 50, 64),
            vrr(8, MP, 50),
            "n ≤ chunk must be a plain accumulation"
        );
    }

    #[test]
    fn product_structure() {
        let v = vrr_chunked(8, MP, 64, 128);
        assert!((0.0..=1.0).contains(&v));
        assert_eq!(v, vrr(8, MP, 64) * vrr(8, interchunk_m_p(8, MP, 64), 128));
    }

    #[test]
    fn monotone_in_m_acc() {
        let n = 1usize << 18;
        let mut prev = vrr_chunked_total(4, MP, n, 64);
        for m in 5..16 {
            let v = vrr_chunked_total(m, MP, n, 64);
            assert!(v >= prev - 1e-9);
            prev = v;
        }
    }
}
