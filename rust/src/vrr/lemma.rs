//! Lemma 1 — variance retention ratio under **full swamping only**
//! (paper Eq. 1):
//!
//! ```text
//!             Σ_{i=2}^{n-1} i·q_i  +  n·q̃_n
//! VRR_full = ───────────────────────────────
//!                        k·n
//! q_i = 2Q(2^{m_acc}/√i)·(1 − 2Q(2^{m_acc}/√(i−1)))
//! q̃_n = 1 − 2Q(2^{m_acc}/√n),   k = Σ q_i + q̃_n
//! ```
//!
//! The implementation reuses each `2Q(2^{m}/√i)` between consecutive
//! iterations (each appears as "crossing now" for `i` and "not before"
//! for `i+1`), halving the erfc count on the `O(n)` loop.

use super::qfunc::tail_prob;
use super::sumq::sum_crossing_terms;

/// `VRR_full_swamping(m_acc, n)` — Lemma 1, Eq. (1).
///
/// Returns 1.0 for `n ≤ 2` (nothing can swamp in a two-term sum under the
/// lemma's surrogate event set — the i-sum is empty and q̃ dominates).
/// The `O(n)` crossing sum runs through the dense+integrated evaluator
/// in [`super::sumq`] (§Perf).
pub fn vrr_full_swamping(m_acc: u32, n: usize) -> f64 {
    if n <= 2 {
        return 1.0;
    }
    let m = m_acc as f64;
    let (mut num, mut k) = sum_crossing_terms(m, 0.0, 2, n);
    let q_tilde = 1.0 - tail_prob(m, n as f64);
    num += n as f64 * q_tilde;
    k += q_tilde;
    if k == 0.0 {
        // Entire surrogate mass underflowed (astronomically long n with
        // tiny m_acc): all variance is lost.
        return 0.0;
    }
    num / (k * n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn high_precision_retains_everything() {
        // Large m_acc ⇒ every q_i vanishes, q̃_n → 1 ⇒ VRR → 1.
        for n in [10, 1_000, 100_000] {
            let v = vrr_full_swamping(24, n);
            assert!((v - 1.0).abs() < 1e-9, "n={n} v={v}");
        }
    }

    #[test]
    fn long_accumulation_loses_variance() {
        // Small m_acc with n far past the knee ⇒ VRR well below 1.
        let v = vrr_full_swamping(4, 100_000);
        assert!(v < 0.5, "v={v}");
    }

    #[test]
    fn monotone_in_m_acc() {
        let n = 50_000;
        let mut prev = vrr_full_swamping(2, n);
        for m in 3..16 {
            let v = vrr_full_swamping(m, n);
            assert!(v >= prev - 1e-12, "m={m}: {v} < {prev}");
            prev = v;
        }
    }

    #[test]
    fn nonincreasing_in_n_past_knee() {
        // Past the knee, more terms ⇒ lower retention.
        let m = 6;
        let knee = 1usize << (2 * m); // threshold crossing scale 2^{2m}
        let mut prev = vrr_full_swamping(m, knee);
        for mult in [2, 4, 8, 16] {
            let v = vrr_full_swamping(m, knee * mult);
            assert!(v <= prev + 1e-9);
            prev = v;
        }
    }

    #[test]
    fn bounded_in_unit_interval() {
        for m in [2, 5, 8, 12] {
            for n in [3, 100, 10_000, 300_000] {
                let v = vrr_full_swamping(m, n);
                assert!((0.0..=1.0 + 1e-12).contains(&v), "m={m} n={n} v={v}");
            }
        }
    }

    #[test]
    fn short_sums_always_fine() {
        assert_eq!(vrr_full_swamping(3, 1), 1.0);
        assert_eq!(vrr_full_swamping(3, 2), 1.0);
        // n = 10 with m_acc = 6: threshold 64σ vs typical |s| ≈ 3σ — no
        // swamping mass, VRR ≈ 1.
        assert!((vrr_full_swamping(6, 10) - 1.0).abs() < 1e-6);
    }
}
