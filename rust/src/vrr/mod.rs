//! The paper's theoretical contribution: the **variance retention ratio**
//! (VRR) of reduced-precision floating-point accumulation.
//!
//! * [`lemma`] — Lemma 1: VRR under full swamping only (Eq. 1).
//! * [`theorem`] — Theorem 1: VRR with partial swamping (Eq. 2), the main
//!   formula `VRR(m_acc, m_p, n)`.
//! * [`chunking`] — Corollary 1: two-level chunked accumulation (Eq. 3).
//! * [`sparsity`] — effective-length corrections (Eqs. 4–5).
//! * [`variance_lost`] — the usage rule `v(n) = e^{n(1-VRR)} < 50`
//!   (Eq. 6), always evaluated in log space.
//! * [`solver`] — inversion: the minimum `m_acc` for a given dot product,
//!   which is what Table 1 is made of.

pub mod chunking;
pub mod lemma;
pub mod qfunc;
pub mod solver;
pub mod sparsity;
mod sumq;
pub mod theorem;
pub mod variance_lost;

pub use chunking::vrr_chunked;
pub use lemma::vrr_full_swamping;
pub use solver::{min_m_acc, AccumSpec};
pub use sparsity::{effective_length, vrr_sparse};
pub use theorem::vrr;
pub use variance_lost::{is_suitable, log_variance_lost, CUTOFF_LN};
