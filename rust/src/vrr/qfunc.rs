//! Probability building blocks of the VRR analysis.
//!
//! Every event probability in the paper is a two-sided Gaussian tail
//! `2Q(2^{m}/√i)` — the probability that a zero-mean partial sum of `i`
//! unit-variance terms exceeds the swamping threshold `2^{m}·σ_p` in
//! magnitude (CLT: `s_i ~ N(0, i·σ_p²)`).

use crate::util::erf::two_q;

/// `2Q(2^{m} / √i)` — `P[|s_i| > 2^m σ_p]` under CLT.
///
/// `m` is a *real* threshold exponent (the partial-swamping stages use
/// `m_acc - m_p + j`), `i` the accumulation index.
#[inline]
pub fn tail_prob(threshold_log2: f64, i: f64) -> f64 {
    debug_assert!(i > 0.0);
    two_q(threshold_log2.exp2() / i.sqrt())
}

/// `q_i = 2Q(2^{m_acc}/√i) · (1 − 2Q(2^{m_acc}/√(i−1)))` — the probability
/// that full swamping first occurs at iteration `i` (paper Eq. 9):
/// crossed the threshold at `i`, had not crossed at `i−1`.
#[inline]
pub fn first_crossing(m_acc: u32, i: usize) -> f64 {
    let cross_now = tail_prob(m_acc as f64, i as f64);
    let not_before = 1.0 - tail_prob(m_acc as f64, (i - 1) as f64);
    cross_now * not_before
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tail_prob_monotone_in_i() {
        // Longer accumulations are more likely to cross the threshold.
        let mut prev = tail_prob(8.0, 1.0);
        for i in 2..2000 {
            let p = tail_prob(8.0, i as f64);
            assert!(p >= prev, "i={i}");
            prev = p;
        }
    }

    #[test]
    fn tail_prob_monotone_in_threshold() {
        for i in [10.0, 1e4, 1e6] {
            let mut prev = tail_prob(2.0, i);
            for m in 3..20 {
                let p = tail_prob(m as f64, i);
                assert!(p <= prev);
                prev = p;
            }
        }
    }

    #[test]
    fn tail_prob_limits() {
        // Tiny threshold vs huge n → prob ≈ 1; huge threshold → ≈ 0.
        assert!(tail_prob(0.0, 1e12) > 0.999);
        assert!(tail_prob(24.0, 10.0) < 1e-300);
    }

    #[test]
    fn first_crossing_is_probability() {
        for i in 2..500 {
            let q = first_crossing(6, i);
            assert!((0.0..=1.0).contains(&q), "q_{i} = {q}");
        }
    }

    #[test]
    fn first_crossing_mass_is_finite_positive() {
        // The surrogate event set is NOT a partition — the paper divides
        // by the normalization constant k for exactly this reason (k can
        // exceed 1 by a lot once i ranges deep past the crossing region).
        let m = 5;
        let n = 20_000;
        let mut mass = 0.0;
        for i in 2..n {
            mass += first_crossing(m, i);
        }
        assert!(mass.is_finite() && mass > 0.0, "mass={mass}");
        // Far below the crossing region (i ≪ 2^{2m}) the mass is negligible.
        let early: f64 = (2..20).map(|i| first_crossing(m, i)).sum();
        assert!(early < 1e-9, "early={early}");
    }
}
