//! Inversion of the VRR analysis: given a dot product's length, product
//! precision, sparsity and accumulation algorithm, find the **minimum
//! accumulator mantissa width** whose normalized variance lost stays
//! under the paper's cut-off. Table 1 is this solver applied to every
//! (layer, GEMM) of the three benchmark networks.

use std::sync::{Arc, OnceLock};

use super::sparsity::{vrr_chunked_sparse_total, vrr_sparse};
use super::variance_lost::is_suitable;
use crate::telemetry::{self, Counter, Histogram, Timer};

/// Description of one accumulation (one GEMM's inner dimension).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AccumSpec {
    /// Nominal accumulation length from the topology.
    pub n: usize,
    /// Product-term mantissa bits (5 for (1,5,2) inputs).
    pub m_p: u32,
    /// Non-zero ratio of incoming product terms (1.0 = dense).
    pub nzr: f64,
    /// Chunk size for two-level accumulation (`None` = sequential).
    pub chunk: Option<usize>,
}

impl AccumSpec {
    /// Dense sequential accumulation with the paper's `m_p = 5`.
    pub fn plain(n: usize) -> AccumSpec {
        AccumSpec {
            n,
            m_p: 5,
            nzr: 1.0,
            chunk: None,
        }
    }

    pub fn with_chunk(mut self, chunk: usize) -> AccumSpec {
        self.chunk = Some(chunk);
        self
    }

    pub fn with_nzr(mut self, nzr: f64) -> AccumSpec {
        self.nzr = nzr;
        self
    }

    /// The VRR of this accumulation for a candidate `m_acc`.
    pub fn vrr(&self, m_acc: u32) -> f64 {
        match self.chunk {
            Some(c) => vrr_chunked_sparse_total(m_acc, self.m_p, self.n, c, self.nzr),
            None => vrr_sparse(m_acc, self.m_p, self.n, self.nzr),
        }
    }

    /// The *effective* length used in the suitability test (sparsity-
    /// corrected): the variance-lost exponent multiplies VRR deficit by
    /// the number of terms that actually accumulate.
    pub fn n_eff(&self) -> usize {
        super::sparsity::effective_length(self.n, self.nzr)
    }

    /// Suitability of a candidate `m_acc` under the `v(n) < 50` rule.
    ///
    /// For a **plain** accumulation this is `v(n_eff) < 50` on Theorem 1's
    /// VRR. For a **chunked** accumulation we require each level to pass
    /// the cut-off *on its own length* (intra: `n₁` at `m_p`; inter: `n₂`
    /// at `min(m_acc, m_p + log₂ n₁)`). Applying the exponent to the total
    /// `n` instead would price the inter-chunk stage's per-term deficit
    /// `n₁`-fold and erase most of the chunking benefit — the per-level
    /// rule is the reading consistent with the paper's Table 1 savings
    /// (up to 6 bits) and Fig. 5b knees; see EXPERIMENTS.md §Table-1 for
    /// the ablation of both readings.
    pub fn suitable(&self, m_acc: u32) -> bool {
        match self.chunk {
            None => is_suitable(self.vrr(m_acc), self.n_eff()),
            Some(c) => {
                if self.n <= c {
                    return is_suitable(
                        super::sparsity::vrr_sparse(m_acc, self.m_p, self.n, self.nzr),
                        self.n_eff(),
                    );
                }
                let n1_eff = super::sparsity::effective_length(c, self.nzr);
                let n2 = self.n.div_ceil(c);
                let n2_eff = n2.min(self.n_eff());
                let intra = super::theorem::vrr(m_acc, self.m_p, n1_eff);
                let m_p2 = super::chunking::interchunk_m_p(m_acc, self.m_p, n1_eff);
                let inter = super::theorem::vrr(m_acc, m_p2, n2_eff);
                is_suitable(intra, n1_eff) && is_suitable(inter, n2_eff)
            }
        }
    }

    /// Ablation: chunked suitability with the variance-lost exponent
    /// applied to the *total* effective length (the conservative reading
    /// of Eqs. (3)+(6)).
    pub fn suitable_total(&self, m_acc: u32) -> bool {
        is_suitable(self.vrr(m_acc), self.n_eff())
    }
}

/// Hard search ceiling: no format the paper considers exceeds f32's 23
/// mantissa bits; 32 leaves margin for ablations.
pub const M_ACC_MAX: u32 = 32;

/// Solver metric handles (`abws_solver_*`), resolved once.
struct SolverTelemetry {
    solves: Arc<Counter>,
    checks: Arc<Counter>,
    wall: Arc<Histogram>,
}

fn solver_telemetry() -> &'static SolverTelemetry {
    static TEL: OnceLock<SolverTelemetry> = OnceLock::new();
    TEL.get_or_init(|| SolverTelemetry {
        solves: telemetry::counter("abws_solver_solves_total"),
        checks: telemetry::counter("abws_solver_suitability_checks_total"),
        wall: telemetry::histogram("abws_solver_wall_ns"),
    })
}

/// Minimum `m_acc` such that the accumulation is suitable.
///
/// Exploits monotonicity of suitability in `m_acc` with a binary search
/// over `[1, M_ACC_MAX]`; returns `M_ACC_MAX` if nothing smaller works.
///
/// Each uncached solve counts into `abws_solver_solves_total` /
/// `abws_solver_suitability_checks_total` and records wall time into
/// `abws_solver_wall_ns` (skipped entirely when telemetry is disabled —
/// every suitability check is O(n), so one `Instant` per solve is noise).
pub fn min_m_acc(spec: &AccumSpec) -> u32 {
    let mut checks = 0u64;
    let _span = if telemetry::trace::enabled() {
        telemetry::trace::TraceSpan::enter("solver.min_m_acc")
            .attr("n", spec.n.to_string())
            .attr(
                "chunk",
                spec.chunk.map_or_else(|| "none".into(), |c| c.to_string()),
            )
    } else {
        telemetry::trace::TraceSpan::noop()
    };
    if !telemetry::enabled() {
        return min_m_acc_counted(spec, &mut checks);
    }
    let timer = Timer::start();
    let m = min_m_acc_counted(spec, &mut checks);
    let tel = solver_telemetry();
    tel.solves.inc();
    tel.checks.add(checks);
    tel.wall.record(timer.elapsed_ns());
    m
}

fn min_m_acc_counted(spec: &AccumSpec, checks: &mut u64) -> u32 {
    let mut check = |m: u32| {
        *checks += 1;
        spec.suitable(m)
    };
    // Binary search for the first suitable width.
    let (mut lo, mut hi) = (1u32, M_ACC_MAX);
    if check(lo) {
        return lo;
    }
    if !check(hi) {
        return M_ACC_MAX;
    }
    // Invariant: !suitable(lo) && suitable(hi).
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if check(mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    hi
}

/// Precision-perturbed width (paper Fig. 6: PP = 0 is the prediction,
/// PP = −1 one bit fewer, …), floored at 1 bit.
pub fn perturbed(m_acc: u32, pp: i32) -> u32 {
    (m_acc as i64 + pp as i64).max(1) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn longer_dots_need_more_bits() {
        let mut prev = 0;
        for log_n in [6, 9, 12, 15, 18, 21] {
            let m = min_m_acc(&AccumSpec::plain(1usize << log_n));
            assert!(m >= prev, "n=2^{log_n}: {m} < {prev}");
            prev = m;
        }
        assert!(prev >= 10, "2^21 should need a wide accumulator ({prev})");
    }

    #[test]
    fn binary_search_matches_linear_scan() {
        for n in [64usize, 1_000, 30_000, 1 << 18] {
            for chunk in [None, Some(64)] {
                let spec = AccumSpec {
                    n,
                    m_p: 5,
                    nzr: 1.0,
                    chunk,
                };
                let fast = min_m_acc(&spec);
                let mut slow = M_ACC_MAX;
                for m in 1..=M_ACC_MAX {
                    if spec.suitable(m) {
                        slow = m;
                        break;
                    }
                }
                assert_eq!(fast, slow, "n={n} chunk={chunk:?}");
            }
        }
    }

    #[test]
    fn chunking_saves_bits() {
        // Paper Table 1: chunking benefits range from 1 to 6 bits on the
        // long GRAD accumulations.
        let n = 1usize << 19;
        let plain = min_m_acc(&AccumSpec::plain(n));
        let chunked = min_m_acc(&AccumSpec::plain(n).with_chunk(64));
        assert!(
            plain >= chunked + 2,
            "plain {plain} vs chunked {chunked}"
        );
        assert!(plain - chunked <= 8, "plain {plain} vs chunked {chunked}");
        // The ablation (total-length exponent) is strictly more
        // conservative than the per-level rule.
        let spec = AccumSpec::plain(n).with_chunk(64);
        for m in 1..=M_ACC_MAX {
            if spec.suitable_total(m) {
                assert!(spec.suitable(m), "total-suitable but per-level not, m={m}");
                break;
            }
        }
    }

    #[test]
    fn sparsity_saves_bits_on_long_dots() {
        let n = 1usize << 20;
        let dense = min_m_acc(&AccumSpec::plain(n));
        let sparse = min_m_acc(&AccumSpec::plain(n).with_nzr(0.1));
        assert!(sparse <= dense);
        assert!(sparse < dense, "dense {dense} sparse {sparse}");
    }

    #[test]
    fn prediction_is_tight() {
        // One bit below the prediction must be unsuitable (this is the
        // tightness the paper demonstrates with PP = −1 in Fig. 6).
        for n in [4_096usize, 1 << 15, 1 << 19] {
            let spec = AccumSpec::plain(n);
            let m = min_m_acc(&spec);
            assert!(spec.suitable(m));
            if m > 1 {
                assert!(!spec.suitable(m - 1), "n={n}: m_acc−1 still suitable");
            }
        }
    }

    #[test]
    fn perturbation_arithmetic() {
        assert_eq!(perturbed(10, 0), 10);
        assert_eq!(perturbed(10, -2), 8);
        assert_eq!(perturbed(1, -3), 1); // floored
        assert_eq!(perturbed(10, 2), 12);
    }

    #[test]
    fn solver_counts_suitability_checks() {
        let spec = AccumSpec::plain(1 << 15);
        let mut checks = 0u64;
        let m = min_m_acc_counted(&spec, &mut checks);
        assert_eq!(m, min_m_acc(&spec));
        // 2 endpoint probes + ≤ ⌈log₂(M_ACC_MAX − 1)⌉ bisection steps.
        assert!((2..=7).contains(&checks), "checks={checks}");
    }

    #[test]
    fn short_dots_need_few_bits() {
        // n = 27 (CIFAR ResNet32 first conv FWD): the paper predicts 6 bits.
        let m = min_m_acc(&AccumSpec::plain(27));
        assert!(m <= 7, "m={m}");
    }
}
