//! Sparsity corrections (paper §4.3, Eqs. 4–5): zero product terms are
//! identity additions, so a dot product of nominal length `n` with
//! non-zero ratio `NZR` behaves like an accumulation of length `NZR·n`.

use super::chunking::interchunk_m_p;
use super::theorem::vrr;

/// Effective accumulation length `⌈NZR·n⌉` (at least 1).
pub fn effective_length(n: usize, nzr: f64) -> usize {
    assert!((0.0..=1.0).contains(&nzr), "NZR must be in [0,1], got {nzr}");
    ((nzr * n as f64).ceil() as usize).max(1)
}

/// Eq. (4): `VRR_sparsity = VRR(m_acc, m_p, NZR·n)`.
pub fn vrr_sparse(m_acc: u32, m_p: u32, n: usize, nzr: f64) -> f64 {
    vrr(m_acc, m_p, effective_length(n, nzr))
}

/// Eq. (5): chunked accumulation with sparse inputs. Sparsity shortens the
/// *intra*-chunk accumulation (`NZR·n₁`) and reduces the inter-chunk input
/// precision growth accordingly. We additionally cap the effective
/// inter-chunk length at the total number of non-zero terms — when inputs
/// are so sparse that most chunks are empty, only `NZR·n₁·n₂` chunk
/// results can be non-zero, and adding a zero chunk result is an identity
/// operation by exactly the paper's §4.3 argument. (Without this cap,
/// Eq. (5) taken literally can make chunking look *worse* than a plain
/// sparse accumulation, which is unphysical.)
pub fn vrr_chunked_sparse(
    m_acc: u32,
    m_p: u32,
    n1: usize,
    n2: usize,
    nzr: f64,
) -> f64 {
    let n1_eff = effective_length(n1, nzr);
    let n2_eff = n2.min(effective_length(n1 * n2, nzr));
    vrr(m_acc, m_p, n1_eff) * vrr(m_acc, interchunk_m_p(m_acc, m_p, n1_eff), n2_eff)
}

/// Eq. (5) over a total length `n` with chunk size `chunk`.
pub fn vrr_chunked_sparse_total(
    m_acc: u32,
    m_p: u32,
    n: usize,
    chunk: usize,
    nzr: f64,
) -> f64 {
    assert!(chunk > 0);
    if n <= chunk {
        return vrr_sparse(m_acc, m_p, n, nzr);
    }
    vrr_chunked_sparse(m_acc, m_p, chunk, n.div_ceil(chunk), nzr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vrr::chunking::vrr_chunked_total;

    const MP: u32 = 5;

    #[test]
    fn dense_is_identity() {
        for n in [100, 10_000] {
            assert_eq!(vrr_sparse(8, MP, n, 1.0), vrr(8, MP, n));
        }
    }

    #[test]
    fn sparsity_raises_vrr() {
        // Shorter effective accumulations retain more variance.
        let n = 1 << 18;
        let dense = vrr_sparse(8, MP, n, 1.0);
        let half = vrr_sparse(8, MP, n, 0.5);
        let tenth = vrr_sparse(8, MP, n, 0.1);
        assert!(half >= dense);
        assert!(tenth >= half);
        assert!(tenth > dense, "tenth {tenth} vs dense {dense}");
    }

    #[test]
    fn effective_length_rounding() {
        assert_eq!(effective_length(100, 0.5), 50);
        assert_eq!(effective_length(101, 0.5), 51); // ceil
        assert_eq!(effective_length(100, 0.0), 1); // floor at 1
        assert_eq!(effective_length(7, 1.0), 7);
    }

    #[test]
    #[should_panic]
    fn nzr_out_of_range_panics() {
        effective_length(10, 1.5);
    }

    #[test]
    fn chunked_sparse_dense_matches_chunked() {
        let n = 1 << 16;
        assert_eq!(
            vrr_chunked_sparse_total(8, MP, n, 64, 1.0),
            vrr_chunked_total(8, MP, n, 64)
        );
    }

    #[test]
    fn chunked_sparse_raises_vrr() {
        let n = 1 << 18;
        let dense = vrr_chunked_sparse_total(6, MP, n, 64, 1.0);
        let sparse = vrr_chunked_sparse_total(6, MP, n, 64, 0.25);
        assert!(sparse >= dense, "sparse {sparse} vs dense {dense}");
    }

    #[test]
    fn sparsity_shrinks_interchunk_growth() {
        // NZR=0.25 on a 64-chunk → effective n1 = 16 → growth log2(16)=4
        // instead of 6.
        assert_eq!(interchunk_m_p(20, 5, effective_length(64, 0.25)), 9);
    }
}
