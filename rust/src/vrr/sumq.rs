//! Fast evaluation of the full-swamping crossing sums shared by Lemma 1
//! and Theorem 1:
//!
//! ```text
//! Σ_{i=start}^{n-1} (i − α)·q_i    and    Σ_{i=start}^{n-1} q_i,
//! q_i = 2Q(2^m/√i)·(1 − 2Q(2^m/√(i−1)))
//! ```
//!
//! The naive loop is `O(n)` erfc calls — 20 ms at `n = 2^20`, 130 ms per
//! `min_m_acc` solve (§Perf log in EXPERIMENTS.md). `q_i` as a function
//! of `i` is smooth on a log axis, so past a dense prefix the sum is a
//! geometric-grid trapezoid integral: `Σ_{i=c}^{n-1} f(i) ≈
//! ∫_{c-1/2}^{n-1/2} f(x) dx` with step ratio 1.0002 (≈5,000 points per
//! e-fold). Both `2Q(2^m/√x)` and `2Q(2^m/√(x−1))` are evaluated exactly
//! at every grid point, so the *only* error is trapezoid-vs-sum —
//! verified < 5e-7 absolute on the VRR against full summation (tests
//! below), two orders tighter than the cross-language golden tolerance.

use super::qfunc::tail_prob;

/// Dense-summation prefix length before switching to integration.
const DENSE_LIMIT: usize = 1 << 15;
/// Geometric grid ratio for the integrated tail.
const RATIO: f64 = 1.0002;

/// Returns `(Σ (i−α)·q_i, Σ q_i)` over `i ∈ [start, n)`.
///
/// `alpha = 0` gives Lemma 1's plain `i` weighting; Theorem 1 passes its
/// partial-swamping horizon (the caller guarantees `start > α`).
pub(crate) fn sum_crossing_terms(m: f64, alpha: f64, start: usize, n: usize) -> (f64, f64) {
    sum_crossing_terms_with(m, alpha, start, n, DENSE_LIMIT)
}

/// As [`sum_crossing_terms`] with an explicit dense prefix — exposed so
/// tests can force full summation (`dense_limit ≥ n`) as the oracle.
pub(crate) fn sum_crossing_terms_with(
    m: f64,
    alpha: f64,
    start: usize,
    n: usize,
    dense_limit: usize,
) -> (f64, f64) {
    if start >= n {
        return (0.0, 0.0);
    }
    let mut num = 0.0;
    let mut k = 0.0;

    let dense_end = n.min(dense_limit.max(start));
    let mut tail_prev = tail_prob(m, (start - 1) as f64);
    for i in start..dense_end {
        let tail_now = tail_prob(m, i as f64);
        let q = tail_now * (1.0 - tail_prev);
        num += (i as f64 - alpha) * q;
        k += q;
        tail_prev = tail_now;
    }

    if dense_end < n {
        // Trapezoid on a geometric grid over x ∈ [dense_end−½, n−½].
        let f = |x: f64| {
            let a_now = tail_prob(m, x);
            let a_prev = tail_prob(m, x - 1.0);
            let q = a_now * (1.0 - a_prev);
            ((x - alpha) * q, q)
        };
        let end = n as f64 - 0.5;
        let mut x0 = dense_end as f64 - 0.5;
        let (mut f0n, mut f0k) = f(x0);
        while x0 < end {
            let x1 = (x0 * RATIO).min(end);
            let (f1n, f1k) = f(x1);
            let h = x1 - x0;
            num += 0.5 * (f0n + f1n) * h;
            k += 0.5 * (f0k + f1k) * h;
            x0 = x1;
            f0n = f1n;
            f0k = f1k;
        }
    }
    (num, k)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The integration fast path against full summation, across the knee.
    #[test]
    fn integrated_tail_matches_dense_sum() {
        for m in [6u32, 8, 10, 12] {
            for n in [1usize << 15, 1 << 17, 1 << 20] {
                let fast = sum_crossing_terms(m as f64, 0.0, 2, n);
                let exact = sum_crossing_terms_with(m as f64, 0.0, 2, n, usize::MAX);
                // Compare the resulting Lemma-1-style ratios (what VRR is
                // built from), not the raw sums (which span 10^12).
                let r_fast = fast.0 / (fast.1.max(1e-300) * n as f64);
                let r_exact = exact.0 / (exact.1.max(1e-300) * n as f64);
                assert!(
                    (r_fast - r_exact).abs() < 5e-7,
                    "m={m} n={n}: {r_fast} vs {r_exact}"
                );
            }
        }
    }

    #[test]
    fn alpha_weighting_consistent() {
        let alpha = 500.0;
        let fast = sum_crossing_terms(8.0, alpha, 501, 1 << 18);
        let exact = sum_crossing_terms_with(8.0, alpha, 501, 1 << 18, usize::MAX);
        assert!(((fast.0 - exact.0) / exact.0).abs() < 1e-5);
        assert!(((fast.1 - exact.1) / exact.1).abs() < 1e-5);
    }

    #[test]
    fn empty_range() {
        assert_eq!(sum_crossing_terms(8.0, 0.0, 100, 100), (0.0, 0.0));
        assert_eq!(sum_crossing_terms(8.0, 0.0, 200, 100), (0.0, 0.0));
    }
}
