//! Theorem 1 — the paper's main formula (Eq. 2): variance retention ratio
//! of a length-`n` accumulation with `m_p`-bit product mantissas and
//! `m_acc`-bit partial-sum mantissas, accounting for **both** full and
//! partial swamping.
//!
//! ```text
//!        Σ_{i=2}^{n-1} (i−α)₊ q_i 1{i>α}
//!      + Σ_{j_r=2}^{m_p} (n−α_{j_r})₊ q'_{j_r} 1{n>α_{j_r}}
//!      + n·k₃
//! VRR = ─────────────────────────────────────────────────────
//!                          k·n
//! ```
//!
//! with `α`/`α_{j_r}` the fractional-variance-loss horizons of the
//! partial-swamping stages (paper Eqs. 13–16), `q'_{j_r}` the boundary
//! events weighted by their expected duration `N_{j_r−1}`, and
//! `k₃ = 1 − 2Q(2^{m_acc−m_p+1}/√n)` the no-swamping mass.

use super::qfunc::tail_prob;

/// Stage-loss partial sums `Σ_{j=1}^{J} 2^j (2^j − 1)(2^{j+1} − 1)`.
fn stage_loss_sum(upto: u32) -> f64 {
    let mut s = 0.0;
    for j in 1..=upto as i32 {
        s += 2f64.powi(j) * (2f64.powi(j) - 1.0) * (2f64.powi(j + 1) - 1.0);
    }
    s
}

/// `α_{j_r} = (2^{m_acc − 3 m_p} / 3) · Σ_{j=1}^{j_r−1} 2^j(2^j−1)(2^{j+1}−1)`.
///
/// `α` (the full-swamping horizon) is `α_{m_p+1}` in this notation, i.e.
/// the sum runs over all `m_p` stages.
pub fn alpha(m_acc: u32, m_p: u32, stages: u32) -> f64 {
    2f64.powi(m_acc as i32 - 3 * m_p as i32) / 3.0 * stage_loss_sum(stages)
}

/// Theorem 1 (Eq. 2): `VRR(m_acc, m_p, n)`.
///
/// * `m_acc` — accumulator mantissa bits (partial sums),
/// * `m_p` — product-term mantissa bits (5 for (1,5,2)×(1,5,2) products),
/// * `n` — accumulation length.
///
/// Returns a value in `[0, 1]` (clamped against ~1e−15 numerical spill).
pub fn vrr(m_acc: u32, m_p: u32, n: usize) -> f64 {
    if n <= 2 {
        return 1.0;
    }
    let nf = n as f64;
    let m = m_acc as f64;

    // --- full-swamping events, variance discounted by the α horizon ----
    let a_full = alpha(m_acc, m_p, m_p); // α
    // Indicator 1{i>α}: start the sum past α (q_i for i ≤ α contributes
    // neither to the numerator nor to k1). The O(n) crossing sum runs
    // through the dense+integrated evaluator in [`super::sumq`] (§Perf).
    let start = if a_full >= (n - 1) as f64 {
        n // sum skipped entirely
    } else {
        (a_full.floor() as usize + 1).max(2)
    };
    let (term1, k1) = super::sumq::sum_crossing_terms(m, a_full, start, n);

    // --- partial-swamping boundary events (stages reached, no full) -----
    let mut term2 = 0.0;
    let mut k2 = 0.0;
    for j_r in 2..=m_p {
        let a_jr = alpha(m_acc, m_p, j_r - 1);
        if nf <= a_jr {
            continue; // indicator 1{n > α_{j_r}}
        }
        // N_{j_r−1} = 2^{m_acc − m_p + j_r}  (expected duration of stage j_r−1)
        let n_prev = 2f64.powi(m_acc as i32 - m_p as i32 + j_r as i32);
        let lo = tail_prob((m_acc + j_r - 1) as f64 - m_p as f64, nf);
        let hi = tail_prob((m_acc + j_r) as f64 - m_p as f64, nf);
        let q_jr = n_prev * lo * (1.0 - hi);
        term2 += (nf - a_jr) * q_jr;
        k2 += q_jr;
    }

    // --- no-swamping mass -----------------------------------------------
    let k3 = 1.0 - tail_prob((m_acc + 1) as f64 - m_p as f64, nf);

    let k = k1 + k2 + k3;
    if k == 0.0 {
        return 0.0;
    }
    ((term1 + term2 + nf * k3) / (k * nf)).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vrr::lemma::vrr_full_swamping;

    const MP: u32 = 5; // products of two (1,5,2) values

    #[test]
    fn high_precision_limit() {
        for n in [100, 10_000, 1_000_000] {
            let v = vrr(24, MP, n);
            assert!((v - 1.0).abs() < 1e-9, "n={n} v={v}");
        }
    }

    #[test]
    fn low_precision_long_accumulation_collapses() {
        // The formula's n→∞ limit decays slowly (the surviving mass sits
        // in the early full-swamping events); well past the knee, less
        // than half the variance is retained and v(n) is astronomical.
        let v = vrr(4, MP, 1_000_000);
        assert!(v < 0.5, "v={v}");
        assert!(
            crate::vrr::variance_lost::log_variance_lost(v, 1_000_000)
                > 100.0 * crate::vrr::variance_lost::CUTOFF_LN
        );
    }

    #[test]
    fn monotone_in_m_acc() {
        // Strict monotonicity holds through the knee; at the saturated
        // end (VRR within ~1e-5 of 1) the surrogate event model admits
        // tiny wiggles, hence the 1e-5 tolerance.
        for n in [1_000, 65_536, 500_000] {
            let mut prev = vrr(3, MP, n);
            for m in 4..20 {
                let v = vrr(m, MP, n);
                assert!(v >= prev - 1e-5, "m={m} n={n}: {v} < {prev}");
                prev = v;
            }
        }
    }

    #[test]
    fn agrees_with_lemma_in_both_limits() {
        // Theorem 1 and Lemma 1 model different event sets, so they are
        // not ordered pointwise — but they must agree in the limits: both
        // ≈1 far before the knee, both far below 1 far past it.
        for m in [6u32, 8] {
            let early = 1usize << (m.saturating_sub(3)); // tiny n
            assert!(vrr(m, MP, early) > 0.999);
            assert!(vrr_full_swamping(m, early) > 0.999);
            let late = 1usize << (2 * m + 4);
            assert!(vrr(m, MP, late) < 0.7, "thm m={m}: {}", vrr(m, MP, late));
            assert!(
                vrr_full_swamping(m, late) < 0.7,
                "lemma m={m}: {}",
                vrr_full_swamping(m, late)
            );
        }
    }

    #[test]
    fn knee_exists_and_is_sharp() {
        // For m_acc = 10 the knee sits around n ~ 2^{2(m_acc-m_p)}…2^{2m_acc};
        // VRR must swing from ≈1 to markedly below 1 within a few octaves.
        let m = 10;
        let early = vrr(m, MP, 1 << 8);
        let late = vrr(m, MP, 1 << 22);
        assert!(early > 0.999, "early={early}");
        assert!(late < 0.9, "late={late}");
    }

    #[test]
    fn bounded_unit_interval() {
        for m in [2, 4, 6, 8, 12, 16] {
            for n in [3, 64, 4_096, 262_144] {
                let v = vrr(m, MP, n);
                assert!((0.0..=1.0).contains(&v), "m={m} n={n} v={v}");
            }
        }
    }

    #[test]
    fn alpha_monotone_in_stages() {
        for s in 1..MP {
            assert!(alpha(10, MP, s) < alpha(10, MP, s + 1));
        }
    }

    #[test]
    fn alpha_scales_with_m_acc() {
        // One more accumulator bit doubles every α horizon.
        let a = alpha(10, MP, MP);
        let b = alpha(11, MP, MP);
        assert!((b / a - 2.0).abs() < 1e-12);
    }

    #[test]
    fn trivial_lengths() {
        assert_eq!(vrr(8, MP, 1), 1.0);
        assert_eq!(vrr(8, MP, 2), 1.0);
    }

    #[test]
    fn more_product_bits_do_not_help_tiny_accumulators() {
        // With m_acc fixed and small, increasing m_p (finer products)
        // increases partial-swamping loss — VRR must not increase.
        let n = 100_000;
        let m_acc = 8;
        let v_coarse = vrr(m_acc, 3, n);
        let v_fine = vrr(m_acc, 8, n);
        assert!(
            v_fine <= v_coarse + 1e-6,
            "fine {v_fine} vs coarse {v_coarse}"
        );
    }
}
