//! The usage rule (paper §4.4, Eq. 6): the **normalized exponential
//! variance lost** `v(n) = e^{n(1 − VRR)}`, with the suitability cut-off
//! `v(n) < 50`.
//!
//! `v(n)` overflows f64 spectacularly past the knee (`n(1−VRR)` reaches
//! thousands), so the library works exclusively with
//! `log v(n) = n·(1 − VRR)` and compares against `ln 50`.

/// `ln 50` — the paper's cut-off in log space.
pub const CUTOFF_LN: f64 = 3.912023005428146; // ln(50)

/// `log v(n) = n · (1 − VRR)` for a VRR already computed by any of the
/// formula variants (plain / chunked / sparse).
#[inline]
pub fn log_variance_lost(vrr_value: f64, n: usize) -> f64 {
    n as f64 * (1.0 - vrr_value)
}

/// The paper's suitability predicate: `v(n) < 50`.
#[inline]
pub fn is_suitable(vrr_value: f64, n: usize) -> bool {
    log_variance_lost(vrr_value, n) < CUTOFF_LN
}

/// `v(n)` itself, saturating at `f64::MAX` — only for display.
pub fn variance_lost(vrr_value: f64, n: usize) -> f64 {
    let lg = log_variance_lost(vrr_value, n);
    if lg > 700.0 {
        f64::INFINITY
    } else {
        lg.exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vrr::theorem::vrr;

    #[test]
    fn cutoff_constant() {
        assert!((CUTOFF_LN - 50f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn perfect_retention_is_suitable() {
        assert!(is_suitable(1.0, 1_000_000));
        assert_eq!(log_variance_lost(1.0, 123), 0.0);
    }

    #[test]
    fn total_loss_is_unsuitable() {
        assert!(!is_suitable(0.0, 100));
        assert_eq!(variance_lost(0.5, 10), (5.0f64).exp());
    }

    #[test]
    fn saturates_instead_of_overflowing() {
        assert_eq!(variance_lost(0.0, 10_000), f64::INFINITY);
        assert!(log_variance_lost(0.0, 10_000).is_finite());
    }

    #[test]
    fn knee_behaviour_with_real_vrr() {
        // For m_acc = 10, m_p = 5: small n suitable, huge n unsuitable.
        let small = 1usize << 8;
        let big = 1usize << 20;
        assert!(is_suitable(vrr(10, 5, small), small));
        assert!(!is_suitable(vrr(10, 5, big), big));
    }

    #[test]
    fn suitability_is_monotone_in_m_acc() {
        // Once suitable at m_acc, every wider accumulator stays suitable.
        let n = 1usize << 16;
        let mut was_suitable = false;
        for m in 2..24 {
            let ok = is_suitable(vrr(m, 5, n), n);
            if was_suitable {
                assert!(ok, "suitability lost at m_acc={m}");
            }
            was_suitable = ok;
        }
        assert!(was_suitable, "never became suitable");
    }
}
