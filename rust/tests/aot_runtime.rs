//! Integration tests over the AOT artifacts + PJRT runtime: the Python
//! compile path and the Rust run path meeting in the middle. The whole
//! file is gated on the `pjrt` cargo feature (the runtime needs the
//! external `xla` bindings), and each test additionally skips gracefully
//! when `make artifacts` has not been run — so `cargo test -q` passes on
//! machines with neither prebuilt artifacts nor a PJRT install.
#![cfg(feature = "pjrt")]

use abws::data::synth::{generate, SynthSpec};
use abws::runtime::{ArtifactStore, Runtime, TrainStepExecutor};
use abws::softfloat::gemm::{rp_gemm_mxu, GemmConfig};
use abws::softfloat::tensor::Tensor;
use abws::util::rng::Pcg64;

fn store() -> Option<ArtifactStore> {
    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    match ArtifactStore::open(root) {
        Ok(s) => {
            if s.verify().is_ok() {
                Some(s)
            } else {
                eprintln!("skipping: artifacts incomplete (run `make artifacts`)");
                None
            }
        }
        Err(_) => {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn kernel_artifact_matches_softfloat_simulator() {
    let Some(store) = store() else { return };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let path = store.root.join("rp_gemm_macc8_chunk64.hlo.txt");
    if !path.exists() {
        eprintln!("skipping: kernel artifact missing");
        return;
    }
    let exe = rt.compile_hlo_file(&path).expect("compile kernel artifact");

    let mut rng = Pcg64::seeded(77);
    let a = Tensor::randn(&[8, 256], 1.0, &mut rng);
    let b = Tensor::randn(&[256, 8], 1.0, &mut rng);
    let la = abws::runtime::client::tensor_to_literal(&a).unwrap();
    let lb = abws::runtime::client::tensor_to_literal(&b).unwrap();
    let out = rt.run(&exe, &[la, lb]).expect("execute kernel");
    let got = abws::runtime::client::literal_to_tensor(&out[0]).unwrap();
    assert_eq!(got.shape, vec![8, 8]);

    // The Rust simulator's MXU-style GEMM implements the same chunked
    // semantics; intra-chunk summation order may differ (XLA dot vs exact
    // f64), so we require near-exact agreement: every element within one
    // accumulator quantum, the bulk exactly equal.
    let want = rp_gemm_mxu(&a, &b, &GemmConfig::paper(8, Some(64)), 64);
    let mut exact = 0usize;
    for (g, w) in got.data.iter().zip(&want.data) {
        let tol = (w.abs().max(1.0) as f64) * 2f64.powi(-7); // one quantum at m_acc=8
        assert!(
            ((g - w).abs() as f64) <= tol,
            "kernel {g} vs simulator {w}"
        );
        if g == w {
            exact += 1;
        }
    }
    assert!(
        exact >= got.data.len() * 9 / 10,
        "only {exact}/{} exactly equal",
        got.data.len()
    );
}

#[test]
fn baseline_artifact_trains_to_convergence() {
    let Some(store) = store() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut exec = TrainStepExecutor::new(&rt, &store, "baseline", 42).unwrap();
    let d = exec.dims;
    let (train, _) = generate(&SynthSpec {
        dim: d.dim,
        classes: d.classes,
        ..Default::default()
    });
    let metrics = exec.train(&train, 50).unwrap();
    assert!(!metrics.diverged);
    let first = metrics.steps.first().unwrap().loss;
    let last = metrics.tail_loss(10).unwrap();
    assert!(last < 0.7 * first, "loss {first} -> {last}");
}

#[test]
fn reduced_precision_artifact_runs() {
    let Some(store) = store() else { return };
    let rt = Runtime::cpu().unwrap();
    for variant in ["macc8", "macc8_chunk64"] {
        let mut exec = TrainStepExecutor::new(&rt, &store, variant, 42).unwrap();
        let d = exec.dims;
        let (train, _) = generate(&SynthSpec {
            dim: d.dim,
            classes: d.classes,
            ..Default::default()
        });
        let metrics = exec.train(&train, 20).unwrap();
        assert!(!metrics.diverged, "{variant} diverged");
        assert!(metrics.steps.len() == 20);
    }
}

#[test]
fn unknown_variant_is_a_clean_error() {
    let Some(store) = store() else { return };
    let rt = Runtime::cpu().unwrap();
    let err = TrainStepExecutor::new(&rt, &store, "definitely_not_a_variant", 0);
    let Err(e) = err else {
        panic!("unknown variant should fail");
    };
    let msg = format!("{e:#}");
    assert!(msg.contains("baseline"), "error should list variants: {msg}");
}

#[test]
fn state_shapes_survive_round_trip() {
    let Some(store) = store() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut exec = TrainStepExecutor::new(&rt, &store, "baseline", 7).unwrap();
    let d = exec.dims;
    let (train, _) = generate(&SynthSpec {
        dim: d.dim,
        classes: d.classes,
        ..Default::default()
    });
    let (xb, yb) = train.batch(0, d.batch);
    exec.step(&xb, &yb).unwrap();
    let (w1, w2) = exec.params().unwrap();
    assert_eq!(w1.shape, vec![d.dim, d.hidden]);
    assert_eq!(w2.shape, vec![d.hidden, d.classes]);
    assert!(w1.data.iter().all(|x| x.is_finite()));
}
