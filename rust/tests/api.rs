//! Integration tests for the `abws::api` advisory layer: memoized
//! solving must be bit-identical to direct evaluation, the request/report
//! types must round-trip through JSON, and the `serve` front-end must
//! answer batches.

use abws::api::cache::{SolveCache, MAX_ENTRIES};
use abws::api::{serve, AdvisorReport, AdvisorRequest, PlanSpec, PrecisionPolicy, TrainRequest};
use abws::nets::layer::{Layer, Network};
use abws::util::json::Json;
use abws::vrr::solver::{min_m_acc, AccumSpec};

/// Satellite requirement: cached `min_m_acc`/`vrr` results must be
/// bit-identical to direct evaluation across a grid of
/// `(m_acc, m_p, n, nzr, chunk)` — on both the miss and the hit path.
#[test]
fn cached_solves_are_bit_identical_across_grid() {
    let cache = SolveCache::new();
    for m_p in [2u32, 5, 7] {
        for n in [27usize, 64, 1_000, 4_096, 1 << 15] {
            for nzr in [1.0, 0.5, 0.05] {
                for chunk in [None, Some(64), Some(256)] {
                    let spec = AccumSpec { n, m_p, nzr, chunk };
                    let direct = min_m_acc(&spec);
                    // First call misses, second must hit — both identical.
                    assert_eq!(cache.min_m_acc(&spec), direct, "{spec:?} (miss)");
                    assert_eq!(cache.min_m_acc(&spec), direct, "{spec:?} (hit)");
                    for m_acc in [4u32, 8, 12] {
                        let want = spec.vrr(m_acc).to_bits();
                        assert_eq!(
                            cache.vrr(&spec, m_acc).to_bits(),
                            want,
                            "{spec:?} m_acc={m_acc} (miss)"
                        );
                        assert_eq!(
                            cache.vrr(&spec, m_acc).to_bits(),
                            want,
                            "{spec:?} m_acc={m_acc} (hit)"
                        );
                    }
                }
            }
        }
    }
    let stats = cache.stats();
    let grid: usize = 3 * 5 * 3 * 3;
    assert_eq!(stats.solve_entries, grid);
    assert_eq!(stats.vrr_entries, grid * 3);
    // One hit per repeated solve + three per repeated vrr query.
    assert_eq!(stats.misses, (grid + grid * 3) as u64);
    assert_eq!(stats.hits, (grid + grid * 3) as u64);
}

/// Satellite requirement: hammer one `SolveCache` from parallel workers.
/// Every query must return the direct-solve value, the hit+miss counters
/// must reconcile exactly with the number of requests issued, and the
/// tables must stay within the capacity bound.
#[test]
fn cache_survives_concurrent_hammering() {
    const WORKERS: usize = 8;
    const OPS: usize = 400;
    // A small key set so workers collide on both the hit and miss paths.
    let mut specs = Vec::new();
    for n in [64usize, 256, 1_000, 4_096] {
        for m_p in [2u32, 5] {
            for chunk in [None, Some(64)] {
                specs.push(AccumSpec {
                    n,
                    m_p,
                    nzr: 0.5,
                    chunk,
                });
            }
        }
    }

    let cache = SolveCache::new();
    std::thread::scope(|s| {
        for w in 0..WORKERS {
            let cache = &cache;
            let specs = &specs;
            s.spawn(move || {
                for i in 0..OPS {
                    // Stagger per worker so threads disagree about which
                    // keys are warm.
                    let spec = &specs[(w * 7 + i) % specs.len()];
                    assert_eq!(cache.min_m_acc(spec), min_m_acc(spec), "{spec:?}");
                    if i % 3 == 0 {
                        let want = spec.vrr(8).to_bits();
                        assert_eq!(cache.vrr(spec, 8).to_bits(), want, "{spec:?}");
                    }
                }
            });
        }
    });

    let stats = cache.stats();
    // Each query increments exactly one of hits/misses — even when two
    // threads race a miss on the same key, both count as misses.
    let vrr_ops_per_worker = OPS.div_ceil(3);
    let total = (WORKERS * (OPS + vrr_ops_per_worker)) as u64;
    assert_eq!(stats.hits + stats.misses, total);
    // At least one miss per distinct key actually queried; far more hits
    // than misses on this small key set.
    assert!(stats.misses >= specs.len() as u64);
    assert!(stats.hits > stats.misses);
    // Capacity bound: entries never exceed the distinct key count, let
    // alone the flush threshold.
    assert!(stats.solve_entries <= specs.len());
    assert!(stats.vrr_entries <= specs.len());
    assert!(stats.solve_entries <= MAX_ENTRIES);
    assert!(stats.vrr_entries <= MAX_ENTRIES);
    assert_eq!(stats.evictions, 0);
}

fn small_custom_net(fc_in: usize) -> Network {
    Network {
        name: "custom".into(),
        batch: 64,
        first_layer: 0,
        layers: vec![
            Layer::conv("conv0", "Stem", 3, 16, 3, 16, 16),
            Layer::fc("fc", "Head", fc_in, 100),
        ],
    }
}

#[test]
fn advisor_request_roundtrips_through_json() {
    let reqs = [
        AdvisorRequest::builtin("resnet18", PrecisionPolicy::paper().with_chunk(Some(64))),
        AdvisorRequest::custom(
            small_custom_net(512),
            PrecisionPolicy::paper().with_m_p(4),
        ),
    ];
    for req in reqs {
        let text = req.to_json().to_string();
        let back = AdvisorRequest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string(), text);
    }
}

#[test]
fn advisor_report_roundtrips_through_json() {
    let report = AdvisorRequest::custom(small_custom_net(512), PrecisionPolicy::paper())
        .run()
        .unwrap();
    let text = report.to_json().to_string();
    let back = AdvisorReport::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back.to_json().to_string(), text);
    assert_eq!(back.render(), report.render());
    assert_eq!(
        back.prediction.group_prediction("Head", "GRAD"),
        report.prediction.group_prediction("Head", "GRAD")
    );
}

#[test]
fn train_request_roundtrips_through_json() {
    let req = TrainRequest {
        plan: PlanSpec::Predicted { pp: -1 },
        dim: 64,
        steps: 10,
        ..Default::default()
    };
    let text = req.to_json().to_string();
    let back = TrainRequest::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back.to_json().to_string(), text);
}

/// Acceptance criterion: `serve` answers a batch of ≥ 100 NDJSON
/// `AdvisorRequest` lines with per-layer `m_acc` predictions.
#[test]
fn serve_answers_a_batch_of_100_requests() {
    let mut input = String::new();
    // 20 repeats over the builtin benchmarks (the memoized fast path)…
    for i in 0..20 {
        let net = ["resnet32", "resnet18", "alexnet"][i % 3];
        input.push_str(&format!("{{\"type\":\"advisor\",\"network\":\"{net}\"}}\n"));
    }
    // …plus 85 distinct custom topologies (each a fresh solve).
    for i in 0..85 {
        let req = AdvisorRequest::custom(
            small_custom_net(256 + 16 * i),
            PrecisionPolicy::paper().with_chunk(Some(64)),
        );
        input.push_str(&req.to_json().to_string());
        input.push('\n');
    }
    let mut out = Vec::new();
    let stats = serve(input.as_bytes(), &mut out).unwrap();
    assert_eq!(stats.requests, 105);
    assert_eq!(stats.errors, 0);

    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 105);
    for line in lines {
        let report = Json::parse(line).unwrap();
        assert_eq!(report.get("type").unwrap().as_str(), Some("advisor_report"));
        let layers = report.get("layers").unwrap().as_arr().unwrap();
        assert!(!layers.is_empty());
        // Every layer carries per-GEMM m_acc predictions; FWD is never
        // N/A for the nets in this batch past the first-layer rule.
        let fwd = layers[0].get("gemms").unwrap().get("FWD").unwrap();
        let m_acc = fwd.get("normal").unwrap().as_f64().unwrap();
        assert!((1.0..=32.0).contains(&m_acc), "m_acc={m_acc}");
        assert!(fwd.get("chunked").unwrap().as_f64().unwrap() <= m_acc);
    }
}

#[test]
fn serve_mixes_advisor_and_train_and_survives_errors() {
    let mut input = String::new();
    input.push_str("{\"type\":\"advisor\",\"network\":\"resnet32\"}\n");
    let train = TrainRequest {
        plan: PlanSpec::Uniform { m_acc: 10 },
        dim: 32,
        classes: 4,
        hidden: 8,
        steps: 5,
        batch: 8,
        n_train: 64,
        n_test: 32,
        ..Default::default()
    };
    input.push_str(&train.to_json().to_string());
    input.push('\n');
    input.push_str("{\"type\":\"advisor\",\"network\":\"not_a_net\"}\n");
    let mut out = Vec::new();
    let stats = serve(input.as_bytes(), &mut out).unwrap();
    assert_eq!(stats.requests, 3);
    assert_eq!(stats.errors, 1);
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(
        Json::parse(lines[0]).unwrap().get("type").unwrap().as_str(),
        Some("advisor_report")
    );
    let trained = Json::parse(lines[1]).unwrap();
    assert_eq!(trained.get("type").unwrap().as_str(), Some("train_report"));
    assert_eq!(trained.get("m_fwd").unwrap().as_f64(), Some(10.0));
    assert_eq!(trained.get("steps_run").unwrap().as_f64(), Some(5.0));
    let bad = Json::parse(lines[2]).unwrap();
    let err = bad.get("error").expect("unknown network yields an error object");
    assert_eq!(err.get("kind").unwrap().as_str(), Some("invalid"));
    // Deprecated top-level string mirrors the structured message for one
    // release (see docs/serve.md).
    assert_eq!(bad.get("message").unwrap().as_str(), err.get("message").unwrap().as_str());
}

/// A `check` request through `serve` agrees with asking the solver
/// directly, and a builder-assembled policy drives both.
#[test]
fn serve_check_requests_agree_with_the_direct_solver() {
    let policy = PrecisionPolicy::builder()
        .m_p(5)
        .chunk(64)
        .build()
        .unwrap();
    let n = 4_096usize;
    let direct = min_m_acc(&policy.accum_spec(n, 1.0));

    let input = format!(
        "{{\"type\":\"check\",\"policy\":{},\"n\":{n},\"m_acc\":{direct},\"id\":\"q\"}}\n",
        policy.to_json()
    );
    let mut out = Vec::new();
    let stats = serve(input.as_bytes(), &mut out).unwrap();
    assert_eq!(stats.requests, 1);
    assert_eq!(stats.errors, 0);

    let report = Json::parse(String::from_utf8(out).unwrap().trim()).unwrap();
    assert_eq!(report.get("type").unwrap().as_str(), Some("check_report"));
    assert_eq!(report.get("min_m_acc").unwrap().as_f64(), Some(direct as f64));
    // The proposed width equals the minimum, so it must be suitable.
    assert_eq!(report.get("suitable").unwrap().as_bool(), Some(true));
    assert_eq!(report.get("id").unwrap().as_str(), Some("q"));
}
