//! Bit-identity regression suite for the parallel reduced-precision GEMM
//! kernel (ISSUE 8 acceptance): the kernel must be byte-identical to the
//! retained scalar reference `rp_gemm_ref` at every thread count, in both
//! rounding modes, under sequential and chunked accumulation, and across
//! the NN/NT/TN layouts — including k=0 and 1×1 edge shapes. Plus a
//! PCG-driven property sweep pinning the fused quantize path against
//! `softfloat::quant::quantize` bit-for-bit from the subnormal range
//! through overflow saturation.

use abws::softfloat::gemm::{
    rp_gemm_ex, rp_gemm_packed, rp_gemm_ref, GemmConfig, GemmCtx, Interrupted, Layout,
    QuantizedOperand,
};
use abws::softfloat::quant::{quantize, Quantizer, Rne, Rtz};
use abws::softfloat::{FpFormat, Rounding, Tensor};
use abws::util::Pcg64;

fn bits(t: &Tensor) -> Vec<u32> {
    t.data.iter().map(|x| x.to_bits()).collect()
}

/// Every GEMM configuration axis the kernel monomorphizes over.
fn configs() -> Vec<GemmConfig> {
    let mut cfgs = Vec::new();
    for mode in [Rounding::NearestEven, Rounding::TowardZero] {
        for chunk in [None, Some(64), Some(7)] {
            let mut cfg = GemmConfig::paper(8, chunk);
            cfg.mode = mode;
            cfgs.push(cfg);
        }
    }
    // Identity formats (the fast path) with and without chunking — the
    // chunked identity config must NOT take the plain-f64 fast path.
    cfgs.push(GemmConfig::baseline());
    let mut chunked_ident = GemmConfig::baseline();
    chunked_ident.chunk = Some(16);
    cfgs.push(chunked_ident);
    cfgs
}

#[test]
fn kernel_is_bit_identical_to_reference_at_every_thread_count() {
    let mut rng = Pcg64::seeded(80);
    let a = Tensor::randn(&[13, 257], 1.0, &mut rng);
    let b = Tensor::randn(&[257, 9], 1.0, &mut rng);
    for cfg in configs() {
        let want = bits(&rp_gemm_ref(&a, &b, &cfg));
        for threads in [1usize, 2, 4] {
            let ctx = GemmCtx {
                threads,
                ..GemmCtx::default()
            };
            let got = rp_gemm_ex(&a, &b, &cfg, Layout::NN, &ctx).unwrap();
            assert_eq!(bits(&got), want, "threads={threads} cfg={cfg:?}");
        }
    }
}

#[test]
fn layouts_are_bit_identical_to_materialized_transposes() {
    let mut rng = Pcg64::seeded(81);
    let a = Tensor::randn(&[6, 70], 1.0, &mut rng);
    let b = Tensor::randn(&[70, 5], 1.0, &mut rng);
    let a_t = a.t(); // [70, 6] — what a TN caller holds
    let b_t = b.t(); // [5, 70] — what an NT caller holds
    for cfg in configs() {
        let want = bits(&rp_gemm_ref(&a, &b, &cfg));
        for threads in [1usize, 2, 4] {
            let ctx = GemmCtx {
                threads,
                ..GemmCtx::default()
            };
            let nt = rp_gemm_ex(&a, &b_t, &cfg, Layout::NT, &ctx).unwrap();
            assert_eq!(bits(&nt), want, "NT threads={threads} cfg={cfg:?}");
            let tn = rp_gemm_ex(&a_t, &b, &cfg, Layout::TN, &ctx).unwrap();
            assert_eq!(bits(&tn), want, "TN threads={threads} cfg={cfg:?}");
        }
    }
}

#[test]
fn edge_shapes_k_zero_and_one_by_one() {
    for cfg in configs() {
        // k = 0: the empty accumulation — all-zero [m, n] output.
        let a = Tensor::zeros(&[3, 0]);
        let b = Tensor::zeros(&[0, 2]);
        for threads in [1usize, 2, 4] {
            let ctx = GemmCtx {
                threads,
                ..GemmCtx::default()
            };
            let out = rp_gemm_ex(&a, &b, &cfg, Layout::NN, &ctx).unwrap();
            assert_eq!(out.shape, vec![3, 2]);
            assert!(out.data.iter().all(|&x| x == 0.0), "cfg={cfg:?}");
        }
        // 1×1×1: one product, one accumulator rounding.
        let a = Tensor::from_vec(&[1, 1], vec![0.37]);
        let b = Tensor::from_vec(&[1, 1], vec![-0.81]);
        let want = bits(&rp_gemm_ref(&a, &b, &cfg));
        for threads in [1usize, 2, 4] {
            let ctx = GemmCtx {
                threads,
                ..GemmCtx::default()
            };
            let out = rp_gemm_ex(&a, &b, &cfg, Layout::NN, &ctx).unwrap();
            assert_eq!(bits(&out), want, "cfg={cfg:?}");
        }
    }
}

#[test]
fn packed_operands_match_unpacked_entry_point() {
    let mut rng = Pcg64::seeded(82);
    let x = Tensor::randn(&[10, 33], 1.0, &mut rng);
    let w = Tensor::randn(&[33, 4], 1.0, &mut rng);
    let ctx = GemmCtx::default();
    for cfg in configs() {
        let xq = QuantizedOperand::for_cfg(&x, &cfg);
        let wq = QuantizedOperand::for_cfg(&w, &cfg);
        assert!(xq.matches(&cfg) && wq.matches(&cfg));
        let packed = rp_gemm_packed(&xq, &wq, &cfg, Layout::NN, &ctx).unwrap();
        let fresh = rp_gemm_ex(&x, &w, &cfg, Layout::NN, &ctx).unwrap();
        assert_eq!(bits(&packed), bits(&fresh), "cfg={cfg:?}");
        // The same pack serves the transposed read (the trainer's W2
        // FWD/BWD sharing): Aᵀ·B via TN against the reference on Aᵀ.
        let via_tn = rp_gemm_packed(&xq, &xq, &cfg, Layout::TN, &ctx).unwrap();
        let want = bits(&rp_gemm_ref(&x.t(), &x, &cfg));
        assert_eq!(bits(&via_tn), want, "cfg={cfg:?}");
    }
}

#[test]
fn deadline_interrupts_between_row_panels() {
    let mut rng = Pcg64::seeded(83);
    let a = Tensor::randn(&[16, 128], 1.0, &mut rng);
    let b = Tensor::randn(&[128, 16], 1.0, &mut rng);
    let ctx = GemmCtx {
        threads: 2,
        deadline: Some(std::time::Instant::now() - std::time::Duration::from_millis(1)),
        ..GemmCtx::default()
    };
    let r = rp_gemm_ex(&a, &b, &GemmConfig::paper(8, Some(64)), Layout::NN, &ctx);
    assert_eq!(r.err(), Some(Interrupted));
}

/// Property sweep: the monomorphized fused quantize path
/// (`Quantizer::quantize_m::<R>`, what the kernel's inner loop calls)
/// must match the free `quantize` bit-for-bit over exponents spanning
/// the flush-to-zero range, target subnormals, normals, and overflow
/// saturation — for every format class the GEMM uses.
#[test]
fn fused_quantize_matches_free_quantize_across_ranges() {
    let formats = [
        FpFormat::FP8_152,         // representation (1,5,2)
        FpFormat::PROD_FP8,        // product (1,6,5)
        FpFormat::accumulator(4),  // narrow accumulator
        FpFormat::accumulator(12), // wide accumulator
        FpFormat::new(11, 52),     // identity (f64-wide)
    ];
    let mut rng = Pcg64::seeded(84);
    for fmt in formats {
        let rne = Quantizer::new(fmt, Rounding::NearestEven);
        let rtz = Quantizer::new(fmt, Rounding::TowardZero);
        for _ in 0..20_000 {
            // Scale a unit normal by 2^[-40, 40): FP8_152 flushes below
            // ~2^-20 and saturates above 2^15·1.75, so the sweep crosses
            // flush, subnormal, normal, and overflow regions of every
            // format above.
            let v = rng.normal() * (2f64).powi(rng.next_below(80) as i32 - 40);
            let want_rne = quantize(v, fmt, Rounding::NearestEven);
            let want_rtz = quantize(v, fmt, Rounding::TowardZero);
            assert_eq!(
                rne.quantize_m::<Rne>(v).to_bits(),
                want_rne.to_bits(),
                "RNE fmt={fmt:?} v={v:e}"
            );
            assert_eq!(
                rtz.quantize_m::<Rtz>(v).to_bits(),
                want_rtz.to_bits(),
                "RTZ fmt={fmt:?} v={v:e}"
            );
        }
        // Specials pass through both paths identically.
        for v in [0.0, -0.0, f64::INFINITY, f64::NEG_INFINITY, f64::NAN] {
            let got = rne.quantize_m::<Rne>(v);
            let want = quantize(v, fmt, Rounding::NearestEven);
            assert_eq!(got.to_bits(), want.to_bits());
        }
    }
}
