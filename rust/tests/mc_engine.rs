//! Bit-identity regression suite for the sweep-vectorized Monte-Carlo
//! VRR engine (ISSUE 9 acceptance): every grid point of a `sweep_vrr`
//! call must bit-match a single-config run of the retained scoped-thread
//! oracle `empirical_vrr_ref` — at 1/2/4 pool threads, under uneven
//! trial splits, for chunked, truncating, and identity-width configs —
//! and the one-config `empirical_vrr` wrapper must agree with both.
//! Plus a PCG-driven property sweep (in the style of `tests/gemm.rs`)
//! pinning the monomorphized accumulate fast paths against the
//! free-`quantize` `*_ref` sums bit-for-bit.

use abws::mc::{
    empirical_vrr, empirical_vrr_ref, sweep_vrr, AccumSetup, Ensemble, McConfig, McError,
};
use abws::softfloat::accumulate::{
    chunked_sum, chunked_sum_ref, pairwise_sum, pairwise_sum_ref, sequential_sum,
    sequential_sum_ref,
};
use abws::softfloat::{FpFormat, Rounding};
use abws::util::Pcg64;

/// The sweep grid every test scores: plain, chunked (even and ragged),
/// truncating, and the `man_bits >= 52` identity fast path.
fn grid() -> Vec<AccumSetup> {
    vec![
        AccumSetup::new(5),
        AccumSetup::new(8),
        AccumSetup::new(5).with_chunk(64),
        AccumSetup::new(5).with_chunk(7), // ragged tail chunks
        AccumSetup::new(8).with_rounding(Rounding::TowardZero),
        AccumSetup::new(8)
            .with_chunk(32)
            .with_rounding(Rounding::TowardZero),
        AccumSetup::new(52), // identity kernel
        AccumSetup::new(52).with_chunk(16),
    ]
}

fn config_for(setup: &AccumSetup, n: usize, trials: usize, seed: u64, threads: usize) -> McConfig {
    let mut cfg = McConfig::new(n, setup.m_acc)
        .with_trials(trials)
        .with_seed(seed)
        .with_rounding(setup.rounding);
    if let Some(c) = setup.chunk {
        cfg = cfg.with_chunk(c);
    }
    cfg.threads = threads;
    cfg
}

/// The headline contract: every sweep point equals the retained oracle,
/// bit for bit, at every thread count — including 33 trials over 4
/// participants (uneven split) and more threads than trials.
#[test]
fn sweep_bit_matches_the_oracle_at_every_thread_count() {
    let grid = grid();
    let (n, trials, seed) = (1_024usize, 33usize, 42u64);
    // Oracle thread count is irrelevant to the bits; use 2 to also cover
    // its own split path.
    let want: Vec<_> = grid
        .iter()
        .map(|s| empirical_vrr_ref(&config_for(s, n, trials, seed, 2)))
        .collect();
    for threads in [1usize, 2, 4, 64] {
        let ens = Ensemble {
            n,
            m_p: 5,
            e_acc: 6,
            sigma_p: 1.0,
            trials,
            seed,
            threads,
        };
        let got = sweep_vrr(&ens, &grid).unwrap();
        assert_eq!(got.len(), grid.len());
        for ((setup, w), g) in grid.iter().zip(&want).zip(&got) {
            assert_eq!(
                g.var_swamping.to_bits(),
                w.var_swamping.to_bits(),
                "threads={threads} setup={setup:?}"
            );
            assert_eq!(g.var_ideal.to_bits(), w.var_ideal.to_bits());
            assert_eq!(g.vrr.to_bits(), w.vrr.to_bits());
            assert_eq!(g.trials, trials);
        }
    }
}

/// The one-config wrapper is literally a width-1 sweep.
#[test]
fn wrapper_agrees_with_sweep_and_oracle() {
    for setup in grid() {
        let cfg = config_for(&setup, 512, 17, 7, 3);
        let via_wrapper = empirical_vrr(&cfg).unwrap();
        let via_oracle = empirical_vrr_ref(&cfg);
        assert_eq!(
            via_wrapper.vrr.to_bits(),
            via_oracle.vrr.to_bits(),
            "{setup:?}"
        );
        assert_eq!(
            via_wrapper.var_swamping.to_bits(),
            via_oracle.var_swamping.to_bits()
        );
        assert_eq!(
            via_wrapper.var_ideal.to_bits(),
            via_oracle.var_ideal.to_bits()
        );
    }
}

/// Degenerate requests come back as structured errors, not NaN results.
#[test]
fn degenerate_requests_are_structured_errors() {
    let ens = |n: usize, trials: usize| Ensemble {
        n,
        m_p: 5,
        e_acc: 6,
        sigma_p: 1.0,
        trials,
        seed: 1,
        threads: 1,
    };
    let g = [AccumSetup::new(8)];
    assert_eq!(sweep_vrr(&ens(64, 1), &g), Err(McError::TooFewTrials(1)));
    assert_eq!(sweep_vrr(&ens(64, 0), &g), Err(McError::TooFewTrials(0)));
    assert_eq!(sweep_vrr(&ens(0, 16), &g), Err(McError::EmptyAccumulation));
    assert_eq!(sweep_vrr(&ens(64, 16), &[]), Err(McError::EmptyGrid));
    assert_eq!(
        sweep_vrr(&ens(64, 16), &[AccumSetup::new(8).with_chunk(0)]),
        Err(McError::ZeroChunk)
    );
    // Two trials is the smallest legal ensemble.
    assert!(sweep_vrr(&ens(64, 2), &g).is_ok());
}

/// Trial counts far from a multiple of the thread count still cover
/// every trial exactly once (97 over 8 participants).
#[test]
fn uneven_trial_splits_are_exact() {
    let g = [AccumSetup::new(9), AccumSetup::new(9).with_chunk(5)];
    let base = sweep_vrr(
        &Ensemble {
            n: 128,
            m_p: 5,
            e_acc: 6,
            sigma_p: 1.0,
            trials: 97,
            seed: 13,
            threads: 1,
        },
        &g,
    )
    .unwrap();
    let split = sweep_vrr(
        &Ensemble {
            n: 128,
            m_p: 5,
            e_acc: 6,
            sigma_p: 1.0,
            trials: 97,
            seed: 13,
            threads: 8,
        },
        &g,
    )
    .unwrap();
    for (a, b) in base.iter().zip(&split) {
        assert_eq!(a.trials, 97);
        assert_eq!(a.vrr.to_bits(), b.vrr.to_bits());
    }
}

/// PCG property sweep over the accumulate layer itself: the
/// monomorphized precomputed-constant fast paths must equal the
/// free-`quantize` reference sums bit-for-bit across formats, modes,
/// chunk sizes, and magnitude ranges (subnormal → overflow), mirroring
/// the fused-quantize sweep in `tests/gemm.rs`.
#[test]
fn accumulate_fast_paths_bit_match_reference_sums() {
    let mut rng = Pcg64::seeded(0xACC);
    let formats = [
        FpFormat::accumulator(4),
        FpFormat::accumulator(9),
        FpFormat::accumulator(14),
        FpFormat::new(11, 52), // identity fast path
    ];
    for &scale in &[1e-30f64, 1e-3, 1.0, 1e3, 1e30] {
        let terms: Vec<f64> = (0..2_048).map(|_| rng.normal() * scale).collect();
        for fmt in formats {
            for mode in [Rounding::NearestEven, Rounding::TowardZero] {
                assert_eq!(
                    sequential_sum(&terms, fmt, mode).to_bits(),
                    sequential_sum_ref(&terms, fmt, mode).to_bits(),
                    "sequential {fmt:?} {mode:?} scale={scale}"
                );
                assert_eq!(
                    pairwise_sum(&terms, fmt, mode).to_bits(),
                    pairwise_sum_ref(&terms, fmt, mode).to_bits(),
                    "pairwise {fmt:?} {mode:?} scale={scale}"
                );
                for chunk in [1usize, 7, 64, 4096] {
                    assert_eq!(
                        chunked_sum(&terms, chunk, fmt, mode).to_bits(),
                        chunked_sum_ref(&terms, chunk, fmt, mode).to_bits(),
                        "chunked c={chunk} {fmt:?} {mode:?} scale={scale}"
                    );
                }
            }
        }
    }
}

/// Grid order is reply order, and the shared ideal ensemble is bitwise
/// identical across every grid entry.
#[test]
fn results_are_in_grid_order_with_one_shared_ideal() {
    let grid = grid();
    let r = sweep_vrr(
        &Ensemble {
            n: 2_048,
            m_p: 5,
            e_acc: 6,
            sigma_p: 1.0,
            trials: 24,
            seed: 5,
            threads: 4,
        },
        &grid,
    )
    .unwrap();
    for x in &r {
        assert_eq!(x.var_ideal.to_bits(), r[0].var_ideal.to_bits());
    }
    // grid[1] (m_acc 8) retains more than grid[0] (m_acc 5); the
    // identity entry retains essentially everything.
    assert!(r[1].vrr > r[0].vrr);
    assert!((r[6].vrr - 1.0).abs() < 1e-9);
}
