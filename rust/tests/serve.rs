//! Integration tests for the pooled `abws::api::serve_with` pipeline:
//! ordered replies, byte-identity with sequential mode, panic isolation,
//! per-request deadlines, and the v1 request envelope.
//!
//! These run in their own test binary (own process, own telemetry
//! registry), but the tests within it still share that registry across
//! threads — telemetry assertions therefore use before/after deltas
//! with `>=` semantics, never exact global equality. Per-call
//! `ServeStats` are exact.

use abws::api::{serve_with, ServeOptions, ServeStats};
use abws::telemetry;
use abws::util::json::Json;

fn run(input: &str, opts: &ServeOptions) -> (String, ServeStats) {
    let mut out = Vec::new();
    let stats = serve_with(input.as_bytes(), &mut out, opts).unwrap();
    (String::from_utf8(out).unwrap(), stats)
}

fn opts(workers: usize) -> ServeOptions {
    ServeOptions {
        workers,
        ..ServeOptions::default()
    }
}

/// A deterministic 1000-line mixed batch: advisors cycling the builtin
/// networks, pointwise checks, tiny seeded training runs, plus planted
/// parse errors and unknown request types.
fn mixed_batch() -> (String, usize) {
    let mut input = String::new();
    let mut errors = 0;
    for i in 0..1000usize {
        let line = if i % 100 == 7 {
            errors += 1;
            format!("this is not json (line {i})\n")
        } else if i % 100 == 57 {
            errors += 1;
            format!("{{\"type\":\"frobnicate\",\"id\":{i}}}\n")
        } else if i % 100 == 31 {
            format!(
                "{{\"type\":\"train\",\"plan\":{{\"kind\":\"baseline\"}},\
                 \"dim\":8,\"classes\":2,\"hidden\":8,\"steps\":3,\"batch\":4,\
                 \"n_train\":32,\"n_test\":16,\"seed\":{i},\"id\":{i}}}\n"
            )
        } else if i % 10 == 3 {
            let n = 256 << (i % 4);
            format!("{{\"type\":\"check\",\"n\":{n},\"m_acc\":9,\"id\":{i}}}\n")
        } else {
            let net = ["resnet32", "resnet18", "alexnet"][i % 3];
            let id = if i % 2 == 0 {
                format!(",\"id\":{i}")
            } else {
                String::new()
            };
            format!("{{\"type\":\"advisor\",\"network\":\"{net}\"{id}}}\n")
        };
        input.push_str(&line);
    }
    (input, errors)
}

/// Acceptance criterion: a 1000-request mixed batch through the pooled
/// pipeline at `--workers 4` is byte-identical to sequential mode, with
/// exactly one reply line per request.
#[test]
fn mixed_batch_of_1000_is_byte_identical_across_worker_counts() {
    let (input, planted_errors) = mixed_batch();

    let pooled = ServeOptions {
        workers: 4,
        queue_depth: 64,
        timeout_ms: None,
    };
    let (out4, stats4) = run(&input, &pooled);
    let (out1, stats1) = run(&input, &opts(1));

    assert_eq!(out4, out1, "pooled output diverged from sequential");
    assert_eq!(stats4, stats1);
    assert_eq!(stats4.requests, 1000);
    assert_eq!(stats4.errors, planted_errors);
    assert_eq!(stats4.timeouts, 0);
    assert_eq!(stats4.panics, 0);
    assert_eq!(out4.lines().count(), 1000, "one reply line per request");

    // Spot-check id echo survives the pooled path on every reply kind.
    for (i, line) in out4.lines().enumerate() {
        let j = Json::parse(line).unwrap();
        let expects_id = i % 100 == 57 || i % 100 == 31 || i % 10 == 3 || i % 2 == 0;
        if i % 100 == 7 {
            // Parse errors have no id to echo.
            assert!(j.get("id").is_none(), "line {i} invented an id");
        } else if expects_id {
            assert_eq!(j.get("id").and_then(Json::as_f64), Some(i as f64), "line {i}");
        }
    }
}

/// A slow first request must not let fast later requests overtake it in
/// the output: replies come back in input-line order, and the telemetry
/// queue-wait/request counters reconcile with the batch.
#[test]
fn replies_stay_in_input_order_despite_out_of_order_completion() {
    let mut input = String::from("{\"type\":\"__sleep\",\"ms\":150,\"id\":\"slow\"}\n");
    let fast = 12usize;
    for i in 0..fast {
        let net = ["resnet32", "resnet18", "alexnet"][i % 3];
        input.push_str(&format!(
            "{{\"type\":\"advisor\",\"network\":\"{net}\",\"id\":{i}}}\n"
        ));
    }

    let before = telemetry::snapshot();
    let (out, stats) = run(&input, &opts(4));
    let delta = telemetry::snapshot().diff(&before);

    assert_eq!(stats.requests, fast + 1);
    assert_eq!(stats.errors, 0);

    let lines: Vec<&str> = out.lines().collect();
    assert_eq!(lines.len(), fast + 1);
    let first = Json::parse(lines[0]).unwrap();
    assert_eq!(first.get("id").and_then(Json::as_str), Some("slow"));
    assert_eq!(
        first.get("type").and_then(Json::as_str),
        Some("__sleep_report"),
        "slow request must still answer first"
    );
    for (i, line) in lines[1..].iter().enumerate() {
        let j = Json::parse(line).unwrap();
        assert_eq!(j.get("id").and_then(Json::as_f64), Some(i as f64), "line {i}");
    }

    // Telemetry reconciles: every request was counted by type and waited
    // in the queue at least once (>=: other tests share the registry).
    let c = |name: &str| delta.counters.get(name).copied().unwrap_or(0);
    assert!(c("abws_serve_requests_total{type=\"advisor\"}") >= fast as u64);
    assert!(c("abws_serve_requests_total{type=\"test\"}") >= 1);
    let wait = &delta.histograms["abws_serve_queue_wait_ns"];
    assert!(wait.count >= (fast + 1) as u64, "queue waits {}", wait.count);
    assert!(
        delta.histograms.contains_key("abws_serve_worker_utilization_pct"),
        "worker utilization histogram missing"
    );
}

/// A panicking handler poisons only its own line: every other request
/// still answers, the panic slot carries a structured `panic` error, and
/// the reply count stays exact.
#[test]
fn panic_is_isolated_to_its_own_reply_line() {
    let input = "{\"type\":\"advisor\",\"network\":\"resnet32\",\"id\":0}\n\
                 {\"type\":\"advisor\",\"network\":\"resnet18\",\"id\":1}\n\
                 {\"type\":\"__panic\",\"id\":7}\n\
                 {\"type\":\"check\",\"n\":1024,\"id\":3}\n\
                 {\"type\":\"advisor\",\"network\":\"alexnet\",\"id\":4}\n";

    let (out, stats) = run(input, &opts(4));
    assert_eq!(stats.requests, 5);
    assert_eq!(stats.errors, 1);
    assert_eq!(stats.panics, 1);

    let lines: Vec<&str> = out.lines().collect();
    assert_eq!(lines.len(), 5, "a panic must not eat its reply line");
    let j = Json::parse(lines[2]).unwrap();
    let err = j.get("error").expect("panic slot carries an error object");
    assert_eq!(err.get("kind").and_then(Json::as_str), Some("panic"));
    assert_eq!(j.get("id").and_then(Json::as_f64), Some(7.0));
    // Deprecated legacy string mirrors the structured message.
    assert_eq!(
        j.get("message").and_then(Json::as_str),
        err.get("message").and_then(Json::as_str)
    );
    for (i, line) in lines.iter().enumerate() {
        if i != 2 {
            let j = Json::parse(line).unwrap();
            assert!(j.get("error").is_none(), "line {i} failed: {line}");
        }
    }
}

/// `--timeout-ms` degrades long requests — both the hidden sleep handler
/// and a genuinely long training run via the trainer's cooperative
/// deadline — to structured `timeout` error lines.
#[test]
fn deadline_degrades_to_structured_timeout_error() {
    let input = "{\"type\":\"__sleep\",\"ms\":2000,\"id\":\"s\"}\n\
                 {\"type\":\"train\",\"plan\":{\"kind\":\"baseline\"},\
                  \"dim\":16,\"classes\":4,\"hidden\":32,\"steps\":100000,\
                  \"batch\":8,\"n_train\":256,\"n_test\":32,\"id\":\"t\"}\n\
                 {\"type\":\"check\",\"n\":512,\"id\":\"ok\"}\n";

    let pooled = ServeOptions {
        workers: 2,
        queue_depth: 8,
        timeout_ms: Some(25),
    };
    let (out, stats) = run(input, &pooled);
    assert_eq!(stats.requests, 3);
    assert_eq!(stats.timeouts, 2);
    assert_eq!(stats.panics, 0);

    let lines: Vec<&str> = out.lines().collect();
    assert_eq!(lines.len(), 3);
    for (line, id) in [(lines[0], "s"), (lines[1], "t")] {
        let j = Json::parse(line).unwrap();
        let err = j.get("error").expect("timed-out slot carries an error");
        assert_eq!(err.get("kind").and_then(Json::as_str), Some("timeout"));
        assert_eq!(j.get("id").and_then(Json::as_str), Some(id));
    }
    let ok = Json::parse(lines[2]).unwrap();
    assert!(ok.get("error").is_none(), "fast request must not time out");
    assert_eq!(ok.get("id").and_then(Json::as_str), Some("ok"));
}

/// The deadline must fire *inside* a long GEMM, not just between steps:
/// this run has exactly one step, so the trainer's pre-step check passes
/// (the deadline is still in the future when step 0 starts) and only the
/// GEMM kernel's between-row-panel poll can stop it. Without in-GEMM
/// cancellation the single step runs to completion and the reply carries
/// no error — so a plain `timeout` assertion pins the behaviour.
#[test]
fn deadline_fires_inside_a_single_long_gemm_step() {
    // [32,1024]·[1024,1024] at m_acc=8: tens of millions of fused
    // quantize-MACs — far beyond the 30 ms budget on any machine.
    let input = "{\"type\":\"train\",\"plan\":{\"kind\":\"uniform\",\"m_acc\":8},\
                 \"dim\":1024,\"classes\":4,\"hidden\":1024,\"steps\":1,\
                 \"batch\":32,\"n_train\":64,\"n_test\":8,\"id\":\"g\"}\n";
    let pooled = ServeOptions {
        workers: 1,
        queue_depth: 8,
        timeout_ms: Some(30),
    };
    let (out, stats) = run(input, &pooled);
    assert_eq!(stats.requests, 1);
    assert_eq!(stats.timeouts, 1, "deadline must interrupt the in-flight GEMM");
    let j = Json::parse(out.lines().next().unwrap()).unwrap();
    let err = j.get("error").expect("timed-out train carries an error");
    assert_eq!(err.get("kind").and_then(Json::as_str), Some("timeout"));
    assert_eq!(j.get("id").and_then(Json::as_str), Some("g"));
}

/// The v1 envelope: missing `"v"` means v1, explicit `"v":1` is
/// accepted, and an unknown version is a structured `invalid` error that
/// still echoes the request id.
#[test]
fn envelope_versions_gate_requests() {
    let input = "{\"v\":1,\"type\":\"check\",\"n\":100,\"id\":\"a\"}\n\
                 {\"type\":\"check\",\"n\":100,\"id\":\"b\"}\n\
                 {\"v\":2,\"type\":\"check\",\"n\":100,\"id\":\"c\"}\n";

    let (out, stats) = run(input, &opts(2));
    assert_eq!(stats.requests, 3);
    assert_eq!(stats.errors, 1);

    let lines: Vec<&str> = out.lines().collect();
    let a = Json::parse(lines[0]).unwrap();
    let b = Json::parse(lines[1]).unwrap();
    assert!(a.get("error").is_none());
    assert_eq!(a.get("min_m_acc"), b.get("min_m_acc"), "v1 == default");

    let c = Json::parse(lines[2]).unwrap();
    let err = c.get("error").unwrap();
    assert_eq!(err.get("kind").and_then(Json::as_str), Some("invalid"));
    assert_eq!(c.get("id").and_then(Json::as_str), Some("c"));
    let msg = err.get("message").and_then(Json::as_str).unwrap();
    assert!(msg.contains("v1"), "error should name the supported version: {msg}");
}

/// The `test` request type runs a real Monte-Carlo sweep through the
/// pooled pipeline (one engine sweep per line, measured next to the
/// theory prediction per width), and a degenerate ensemble degrades to
/// a structured `invalid` error line in its slot — not a NaN report.
#[test]
fn test_requests_measure_and_degenerate_ones_error() {
    let input = "{\"type\":\"test\",\"n\":512,\"m_accs\":[6,12],\"trials\":16,\"id\":\"m\"}\n\
                 {\"type\":\"test\",\"n\":512,\"m_acc\":8,\"trials\":1,\"id\":\"bad\"}\n\
                 {\"type\":\"check\",\"n\":256,\"id\":\"ok\"}\n";
    let (out, stats) = run(input, &opts(2));
    assert_eq!(stats.requests, 3);
    assert_eq!(stats.errors, 1);
    assert_eq!(stats.panics, 0);

    let lines: Vec<&str> = out.lines().collect();
    assert_eq!(lines.len(), 3);

    let report = Json::parse(lines[0]).unwrap();
    assert!(report.get("error").is_none(), "{}", lines[0]);
    assert_eq!(report.get("type").and_then(Json::as_str), Some("test_report"));
    assert_eq!(report.get("id").and_then(Json::as_str), Some("m"));
    let points = report.get("points").and_then(Json::as_arr).unwrap();
    assert_eq!(points.len(), 2, "one point per requested width");
    let vrr = |p: &Json| p.get("measured").and_then(Json::as_f64).unwrap();
    assert!(
        vrr(&points[1]) > vrr(&points[0]),
        "wider accumulator must retain more: {}",
        lines[0]
    );

    let bad = Json::parse(lines[1]).unwrap();
    let err = bad.get("error").expect("degenerate ensemble is an error");
    assert_eq!(err.get("kind").and_then(Json::as_str), Some("invalid"));
    assert_eq!(bad.get("id").and_then(Json::as_str), Some("bad"));
    let msg = err.get("message").and_then(Json::as_str).unwrap();
    assert!(msg.contains("at least 2"), "{msg}");

    let ok = Json::parse(lines[2]).unwrap();
    assert!(ok.get("error").is_none());
}

/// `workers: 0` resolves to the detected parallelism rather than a
/// zero-thread deadlock.
#[test]
fn zero_workers_means_auto_detect() {
    let input = "{\"type\":\"check\",\"n\":64,\"id\":1}\n";
    let (out, stats) = run(input, &opts(0));
    assert_eq!(stats.requests, 1);
    assert_eq!(stats.errors, 0);
    let j = Json::parse(out.lines().next().unwrap()).unwrap();
    assert_eq!(j.get("id").and_then(Json::as_f64), Some(1.0));
}
