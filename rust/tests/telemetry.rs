//! Integration tests for the `abws::telemetry` subsystem as wired
//! through the serve front-end and the process-wide solve cache.
//!
//! These run in their own test binary (own process, own global
//! registry), but the tests within it still share that registry across
//! threads — so assertions on metrics other tests may touch use `>=` or
//! before/after deltas, never exact global equality. Uniquely-named
//! probe metrics get exact assertions.

use abws::api::serve;
use abws::telemetry;
use abws::util::json::Json;

/// Build an NDJSON batch: `advisors` builtin advisor requests cycling
/// the three benchmark networks, plus `bad` malformed lines and
/// `unknown` unknown-type lines.
fn batch(advisors: usize, bad: usize, unknown: usize) -> String {
    let mut input = String::new();
    for i in 0..advisors {
        let net = ["resnet32", "resnet18", "alexnet"][i % 3];
        input.push_str(&format!("{{\"type\":\"advisor\",\"network\":\"{net}\"}}\n"));
    }
    for _ in 0..bad {
        input.push_str("this is not json\n");
    }
    for _ in 0..unknown {
        input.push_str("{\"type\":\"frobnicate\"}\n");
    }
    input
}

/// Acceptance criterion: a 1000-request batch through `serve` emits a
/// JSON telemetry snapshot containing latency p50/p95/p99, per-type
/// request counts, and the SolveCache hit counters.
#[test]
fn serve_batch_of_1000_emits_full_telemetry() {
    let before = telemetry::snapshot();
    let input = batch(990, 6, 4);

    let mut out = Vec::new();
    let stats = serve(input.as_bytes(), &mut out).unwrap();
    assert_eq!(stats.requests, 1000);
    assert_eq!(stats.errors, 10);
    assert_eq!(String::from_utf8(out).unwrap().lines().count(), 1000);

    let delta = telemetry::snapshot().diff(&before);

    // Per-type request counts (>=: other tests in this binary may also
    // drive serve concurrently, only adding to the deltas).
    let c = |name: &str| delta.counters.get(name).copied().unwrap_or(0);
    assert!(c("abws_serve_requests_total{type=\"advisor\"}") >= 990);
    assert!(c("abws_serve_requests_total{type=\"invalid\"}") >= 6);
    assert!(c("abws_serve_requests_total{type=\"unknown\"}") >= 4);
    assert!(c("abws_serve_errors_total") >= 10);

    // Latency histogram with sane quantiles.
    let lat = &delta.histograms["abws_serve_latency_ns"];
    assert!(lat.count >= 1000, "latency count {}", lat.count);
    let (p50, p95, p99) = (lat.quantile(0.5), lat.quantile(0.95), lat.quantile(0.99));
    assert!(p50 > 0.0, "p50={p50}");
    assert!(p50 <= p95 && p95 <= p99, "p50={p50} p95={p95} p99={p99}");

    // The repeated builtin sweeps ride the memoized global SolveCache:
    // its collector-exported hit counter must have moved.
    assert!(c("abws_cache_hits_total") >= 1, "no cache hits recorded");
    assert!(
        delta.counters.contains_key("abws_cache_misses_total"),
        "cache collector missing from snapshot"
    );

    // And the snapshot serializes with the quantiles in place.
    let j = delta.to_json();
    let lat_json = j.get("histograms").unwrap().get("abws_serve_latency_ns").unwrap();
    for key in ["count", "p50", "p95", "p99", "buckets"] {
        assert!(lat_json.get(key).is_some(), "missing histogram key {key}");
    }
    // The emitted snapshot is itself valid JSON text.
    let reparsed = Json::parse(&j.to_string()).unwrap();
    assert!(reparsed.get("counters").is_some());
}

/// Acceptance criterion: the Prometheus export parses as text
/// exposition — every non-comment line is `name{labels} value` with a
/// numeric value and a legal metric name; histograms expose cumulative
/// buckets ending at `+Inf` plus `_sum`/`_count`.
#[test]
fn prometheus_export_is_valid_exposition() {
    // Drive a little traffic so the export is non-trivial.
    let mut out = Vec::new();
    serve(batch(12, 1, 1).as_bytes(), &mut out).unwrap();

    let text = telemetry::snapshot().prometheus();
    assert!(!text.is_empty());

    let name_ok = |name: &str| {
        !name.is_empty()
            && name
                .chars()
                .all(|ch| ch.is_ascii_alphanumeric() || ch == '_' || ch == ':')
            && !name.starts_with(|ch: char| ch.is_ascii_digit())
    };

    let mut samples = 0usize;
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut parts = rest.split_whitespace();
            let name = parts.next().unwrap_or("");
            let kind = parts.next().unwrap_or("");
            assert!(name_ok(name), "bad TYPE name in {line:?}");
            assert!(
                matches!(kind, "counter" | "gauge" | "histogram"),
                "bad TYPE kind in {line:?}"
            );
            continue;
        }
        assert!(!line.starts_with('#'), "unexpected comment {line:?}");
        let (series, value) = line.rsplit_once(' ').expect("sample without value");
        value.parse::<f64>().unwrap_or_else(|_| panic!("bad value in {line:?}"));
        let name = series.split('{').next().unwrap();
        assert!(name_ok(name), "bad metric name in {line:?}");
        if series.contains('{') {
            assert!(series.ends_with('}'), "unbalanced labels in {line:?}");
        }
        samples += 1;
    }
    assert!(samples > 0, "no samples in exposition");

    // Histogram expansion for the serve latency series.
    assert!(text.contains("# TYPE abws_serve_latency_ns histogram"));
    assert!(text.contains("abws_serve_latency_ns_bucket{le=\"+Inf\"}"));
    assert!(text.contains("abws_serve_latency_ns_sum"));
    assert!(text.contains("abws_serve_latency_ns_count"));
    // Cumulative bucket counts are non-decreasing, with +Inf == _count.
    let buckets: Vec<f64> = text
        .lines()
        .filter(|l| l.starts_with("abws_serve_latency_ns_bucket"))
        .map(|l| l.rsplit_once(' ').unwrap().1.parse::<f64>().unwrap())
        .collect();
    assert!(buckets.len() >= 2, "expected several buckets");
    assert!(
        buckets.windows(2).all(|w| w[0] <= w[1]),
        "buckets not cumulative: {buckets:?}"
    );

    // Per-type serve counters survive the label round-trip.
    assert!(text.contains("abws_serve_requests_total{type=\"advisor\"}"));
}

/// Snapshot diffing isolates activity between two points in time: a
/// uniquely-named probe metric shows exactly what this test did, even
/// with unrelated tests hammering the same registry.
#[test]
fn snapshot_diff_isolates_probe_activity() {
    let probe_c = telemetry::counter("itest_diff_probe_total");
    let probe_h = telemetry::histogram("itest_diff_probe_ns");
    probe_c.inc(); // pre-baseline noise the diff must subtract away

    let before = telemetry::snapshot();
    probe_c.add(3);
    probe_h.record(100);
    probe_h.record(200_000);
    let delta = telemetry::snapshot().diff(&before);

    assert_eq!(delta.counters["itest_diff_probe_total"], 3);
    let h = &delta.histograms["itest_diff_probe_ns"];
    assert_eq!(h.count, 2);
    assert_eq!(h.sum, 200_100);
    assert!(h.quantile(0.5) > 0.0);

    // A brand-new metric (absent from the baseline) passes through.
    telemetry::counter("itest_diff_probe_fresh_total").inc();
    let delta2 = telemetry::snapshot().diff(&before);
    assert_eq!(delta2.counters["itest_diff_probe_fresh_total"], 1);
}
