//! Integration tests for request-scoped tracing: span-tree propagation
//! through the serve pipeline and worker pool, chrome-trace golden
//! shape, id determinism under a fixed seed, and the flight-recorder
//! dump on request timeout.
//!
//! Trace state (the enabled flag, the id counter, the flight-recorder
//! ring, the dump path) is process-global, so every test here serializes
//! on one file-local mutex and leaves tracing disabled on exit.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Duration;

use abws::api::{serve_with, ServeOptions, ServeStats};
use abws::telemetry::trace::{self, SpanRecord, TraceSpan};
use abws::util::json::Json;

static LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with tracing enabled under `seed`, returning the drained
/// flight recorder. Leaves tracing disabled.
fn with_trace<F: FnOnce()>(seed: u64, f: F) -> Vec<SpanRecord> {
    trace::clear();
    trace::reseed(seed);
    trace::set_enabled(true);
    f();
    trace::set_enabled(false);
    trace::drain_spans()
}

fn serve(input: &str, opts: &ServeOptions) -> (String, ServeStats) {
    let mut out = Vec::new();
    let stats = serve_with(input.as_bytes(), &mut out, opts).unwrap();
    (String::from_utf8(out).unwrap(), stats)
}

/// A tiny training request: two steps through real reduced-precision
/// GEMMs, enough to produce gemm/pool-region/panel spans.
fn train_line(id: &str) -> String {
    format!(
        "{{\"type\":\"train\",\"plan\":{{\"kind\":\"uniform\",\"m_acc\":10}},\
         \"dim\":16,\"classes\":4,\"hidden\":8,\"steps\":2,\"batch\":8,\
         \"n_train\":32,\"n_test\":16,\"id\":\"{id}\"}}\n"
    )
}

/// Walk `span`'s parent chain to its root, returning the names seen
/// (innermost first, root last).
fn ancestry<'a>(spans: &'a [SpanRecord], span: &'a SpanRecord) -> Vec<&'a SpanRecord> {
    let by_id: HashMap<u64, &SpanRecord> = spans.iter().map(|s| (s.span_id, s)).collect();
    let mut chain = vec![span];
    let mut cur = span;
    while cur.parent_id != 0 {
        match by_id.get(&cur.parent_id) {
            Some(p) => {
                chain.push(p);
                cur = p;
            }
            None => break,
        }
    }
    chain
}

/// Tentpole acceptance: at every pooled worker count, a serve train
/// request's span tree reaches from `serve.request` through the pool
/// region down to a GEMM row panel, with consistent trace ids.
#[test]
fn serve_span_tree_reaches_gemm_panels_at_every_worker_count() {
    let _g = LOCK.lock().unwrap();
    for workers in [1usize, 2, 4] {
        let opts = ServeOptions {
            workers,
            queue_depth: 8,
            timeout_ms: None,
        };
        let input = train_line("t0");
        let spans = with_trace(100 + workers as u64, || {
            let (_, stats) = serve(&input, &opts);
            assert_eq!(stats.requests, 1);
            assert_eq!(stats.errors, 0);
        });

        let req = spans
            .iter()
            .find(|s| s.name == "serve.request")
            .unwrap_or_else(|| panic!("workers={workers}: no serve.request span"));
        assert_eq!(req.parent_id, 0, "request span must be a trace root");
        assert!(
            req.attrs.iter().any(|(k, v)| *k == "type" && v == "train"),
            "request span should carry its type: {:?}",
            req.attrs
        );

        let panel = spans
            .iter()
            .filter(|s| s.name == "gemm.panel")
            .find(|s| ancestry(&spans, s).last().unwrap().span_id == req.span_id)
            .unwrap_or_else(|| panic!("workers={workers}: no panel under the request"));
        let chain = ancestry(&spans, panel);
        let names: Vec<&str> = chain.iter().map(|s| s.name).collect();
        assert_eq!(names.first(), Some(&"gemm.panel"), "{names:?}");
        assert_eq!(names.last(), Some(&"serve.request"), "{names:?}");
        assert!(names.contains(&"pool.region"), "workers={workers}: {names:?}");
        assert!(names.contains(&"gemm"), "workers={workers}: {names:?}");
        assert!(
            chain.iter().all(|s| s.trace_id == req.trace_id),
            "workers={workers}: trace id must be shared down the chain"
        );

        // The panel's immediate parent is the pool region that ran it.
        let region = chain[1..]
            .iter()
            .find(|s| s.name == "pool.region")
            .unwrap();
        assert_eq!(panel.parent_id, region.span_id, "workers={workers}");
    }
}

/// Replace wall-clock ids/times with stable small values so the chrome
/// export can be compared against a checked-in golden file: ids are
/// renumbered in (start, id) order, timestamps become the event index.
fn canonicalize(spans: &[SpanRecord]) -> Vec<SpanRecord> {
    let mut sorted: Vec<SpanRecord> = spans.to_vec();
    sorted.sort_by_key(|r| (r.start_ns, r.span_id));
    let ids: HashMap<u64, u64> = sorted
        .iter()
        .enumerate()
        .map(|(i, r)| (r.span_id, i as u64 + 1))
        .collect();
    let mut traces: HashMap<u64, u64> = HashMap::new();
    for r in &sorted {
        let next = traces.len() as u64 + 1;
        traces.entry(r.trace_id).or_insert(next);
    }
    sorted
        .iter()
        .enumerate()
        .map(|(i, r)| SpanRecord {
            trace_id: traces[&r.trace_id],
            span_id: i as u64 + 1,
            parent_id: ids.get(&r.parent_id).copied().unwrap_or(0),
            start_ns: i as u64 * 1000,
            dur_ns: 0,
            tid: 0,
            ..r.clone()
        })
        .collect()
}

/// Golden test for the chrome://tracing JSON shape. Regenerate with
/// `UPDATE_GOLDEN=1 cargo test --test trace`.
#[test]
fn chrome_trace_export_matches_golden_shape() {
    let _g = LOCK.lock().unwrap();
    let spans = with_trace(42, || {
        let _r = TraceSpan::enter("serve.request").attr("type", "advisor");
        // Distinct start timestamps keep the canonical order stable.
        std::thread::sleep(Duration::from_millis(1));
        let _s = TraceSpan::enter("solver.min_m_acc").attr("n", "4096");
    });
    assert_eq!(spans.len(), 2);
    let got = trace::chrome_trace_json(&canonicalize(&spans)).to_string();

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/chrome_trace.json");
    if std::env::var("UPDATE_GOLDEN").is_ok() {
        std::fs::write(&path, format!("{got}\n")).unwrap();
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        got,
        want.trim_end(),
        "chrome-trace shape drifted; rerun with UPDATE_GOLDEN=1 and review"
    );
    // And the export always round-trips through the strict parser.
    assert!(Json::parse(&got).is_ok());
}

/// The id generator is a pure function of (seed, counter): replaying the
/// same single-threaded workload after the same reseed yields identical
/// trace/span/parent ids, and a different seed yields different ones.
#[test]
fn trace_ids_are_deterministic_under_a_fixed_seed() {
    let _g = LOCK.lock().unwrap();
    let run = |seed: u64| {
        let spans = with_trace(seed, || {
            let _a = TraceSpan::enter("outer");
            let _b = TraceSpan::enter("middle");
            let _c = TraceSpan::enter("inner");
        });
        spans
            .iter()
            .map(|s| (s.name, s.trace_id, s.span_id, s.parent_id))
            .collect::<Vec<_>>()
    };
    let first = run(7);
    assert_eq!(first.len(), 3);
    assert_eq!(first, run(7), "same seed must replay identical ids");
    assert_ne!(first, run(8), "different seed must shift ids");
}

/// Acceptance criterion: a serve request that times out leaves a flight
/// recorder dump on disk whose span tree reaches from the request span
/// down to a GEMM row panel.
#[test]
fn timed_out_request_dumps_span_tree_to_configured_path() {
    let _g = LOCK.lock().unwrap();
    let path = std::env::temp_dir().join(format!(
        "abws_trace_timeout_dump_{}.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);

    trace::clear();
    trace::reseed(55);
    trace::set_dump_path(Some(path.clone()));
    trace::set_enabled(true);
    // Far more steps than the deadline allows: a few steps complete
    // (recording their spans), then the cooperative deadline degrades
    // the request to a structured timeout and serve dumps the ring.
    let input = "{\"type\":\"train\",\"plan\":{\"kind\":\"uniform\",\"m_acc\":10},\
                 \"dim\":64,\"classes\":4,\"hidden\":64,\"steps\":100000,\
                 \"batch\":16,\"n_train\":64,\"n_test\":16,\"id\":\"slow\"}\n";
    let opts = ServeOptions {
        workers: 2,
        queue_depth: 8,
        timeout_ms: Some(150),
    };
    let (_, stats) = serve(input, &opts);
    trace::set_enabled(false);
    trace::set_dump_path(None);
    trace::clear();
    assert_eq!(stats.timeouts, 1, "the train request must time out");

    let text = std::fs::read_to_string(&path).expect("timeout must write a dump");
    let _ = std::fs::remove_file(&path);
    let dump = Json::parse(&text).unwrap();
    let events = dump.get("traceEvents").and_then(Json::as_arr).unwrap();
    assert!(!events.is_empty());

    // Rebuild the tree from the dumped args and walk panel -> request.
    let id_of = |e: &Json, key: &str| {
        let hex = e.get("args").unwrap().get(key).unwrap().as_str().unwrap();
        u64::from_str_radix(hex, 16).unwrap()
    };
    let by_id: HashMap<u64, &Json> = events.iter().map(|e| (id_of(e, "span_id"), e)).collect();
    let name_of = |e: &Json| e.get("name").unwrap().as_str().unwrap().to_string();
    let leaf = events
        .iter()
        .find(|e| {
            let n = name_of(e);
            n == "gemm.panel" || n == "mc.trial"
        })
        .expect("dump must contain a GEMM row-panel or MC-trial span");
    let mut cur = leaf;
    let mut names = vec![name_of(cur)];
    while id_of(cur, "parent_id") != 0 {
        match by_id.get(&id_of(cur, "parent_id")) {
            Some(p) => {
                cur = p;
                names.push(name_of(cur));
            }
            None => break,
        }
    }
    assert_eq!(
        names.last().map(String::as_str),
        Some("serve.request"),
        "dumped tree must reach the request span: {names:?}"
    );
}
