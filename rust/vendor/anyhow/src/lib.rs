//! Minimal vendored stand-in for the `anyhow` crate (the build is fully
//! offline — no crates.io registry). API-compatible with the subset this
//! workspace uses: [`Error`], [`Result`], the [`Context`] extension trait
//! on `Result` and `Option`, and the `anyhow!` / `bail!` / `ensure!`
//! macros. As in real `anyhow`, plain `{}` formatting shows the outermost
//! message and alternate `{:#}` formatting shows the whole context chain
//! joined with `": "`.

use std::fmt;

/// A context-chained error. `chain[0]` is the outermost message; the last
/// entry is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error {
            chain: vec![msg.to_string()],
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, ctx: C) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// The same coherence pattern real `anyhow` relies on: `Error` itself does
// NOT implement `std::error::Error`, so this blanket impl is disjoint
// from the std reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow`-style result alias with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err()
            .context("loading experiment");
        assert_eq!(format!("{e}"), "loading experiment");
        assert_eq!(format!("{e:#}"), "loading experiment: reading config: gone");
        assert!(format!("{e:?}").contains("Caused by:"));
        assert_eq!(e.root_cause(), "gone");
        assert_eq!(e.chain().count(), 3);
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e:#}"), "missing value");
        assert_eq!(Some(7u32).context("unused").unwrap(), 7);
    }

    #[test]
    fn macros() {
        fn fails(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(fails(2).unwrap(), 2);
        assert_eq!(format!("{:#}", fails(3).unwrap_err()), "three is right out");
        assert_eq!(format!("{:#}", fails(12).unwrap_err()), "x too big: 12");
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }

    #[test]
    fn question_mark_conversion() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }
}
