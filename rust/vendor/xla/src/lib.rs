//! Placeholder for the `xla` PJRT bindings crate.
//!
//! The `pjrt` cargo feature needs the real `xla-rs` crate
//! (github.com/LaurentMazare/xla-rs) plus a libxla install; the offline
//! build cannot fetch it, so this stub exists only to turn
//! `cargo build --features pjrt` into one actionable diagnostic instead
//! of a page of unresolved-import errors. Replace this directory with
//! the real crate (same path, `rust/vendor/xla`) to enable the runtime.

compile_error!(
    "the `pjrt` feature needs the real `xla` bindings crate: replace \
     rust/vendor/xla with a vendored copy of xla-rs \
     (github.com/LaurentMazare/xla-rs) and install libxla, then rebuild \
     with --features pjrt"
);
